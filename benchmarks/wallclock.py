"""Wall-clock truth harness — measured time, honestly bounded.

Every number this module emits follows the same methodology:

  1. the measured callable is jitted and called ``warmup`` times first, so
     trace + compile time is EXCLUDED from every reported figure (the
     ``us_total`` column of benchmarks/run.py deliberately includes it;
     this file is the per-call complement);
  2. every timed call is fenced with ``jax.block_until_ready`` — async
     dispatch means an unfenced ``time.perf_counter`` pair measures queue
     submission, not execution (the same bug class as the per-step
     ``float(metrics["loss"])`` sync that launch/train.py used to have);
  3. the reported figure is the MEDIAN of ``reps`` fenced calls with the
     inter-quartile range as spread — never a single sample, never a mean
     that one scheduler hiccup can poison.

Tables (one CSV each under benchmarks/results/, all rows in BENCH_7.json):

  * ``gemm``        — one ``kernels.ops.sparse_gemm`` dispatch per schedule
                      ∈ {predicated, compact, dense} for one CNN-derived
                      workload (dims from ``CNNModel.gemm_workload``) and
                      one FFN workload (the backward dX GEMM the paper's
                      output sparsity targets);
  * ``train_step``  — one whole jitted train step of models/cnn.py and
                      models/ffn.py (forward + backward + SGD update);
  * ``autotune``    — the decision log of a scripted autotune session
                      (``autotune_session``): sparse→dense drift retunes
                      plus per-(spec, shape) keyed selections, every row
                      traceable to its measured live fraction.

``BENCH_7.json`` at the repo root is schema-stable: ``check_schema``
validates the exact key set per table and the acceptance coverage (every
schedule measured for ≥1 CNN and ≥1 FFN workload); CI runs the smoke
geometry and fails on drift.  See docs/benchmarking.md.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import statistics
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_7.json")

SCHEMA_VERSION = 1
SCHEDULES = ("predicated", "compact", "dense")

# The exact per-table row key sets BENCH_7.json commits to.  check_schema
# fails on ANY deviation — added keys are drift just like missing ones.
ROW_KEYS = {
    "gemm": ("table", "workload", "schedule", "m", "k", "n", "groups",
             "block", "us_median", "us_iqr", "reps", "warmup"),
    "train_step": ("table", "workload", "schedule", "batch", "params",
                   "us_median", "us_iqr", "reps", "warmup"),
}
AUTOTUNE_LOG_KEYS = ("seq", "event", "key", "shape", "groups", "schedule",
                     "block", "live_frac", "operand_frac", "samples")


# ---------------------------------------------------------------------------
# The one timing primitive
# ---------------------------------------------------------------------------

def measure(call: Callable[[], object], *, warmup: int = 2,
            reps: int = 5) -> Dict[str, float]:
    """Median-of-``reps`` fenced wall time of ``call`` in µs, compile
    excluded.

    ``call`` must return its device output (a jitted function application):
    the first of the ``warmup`` calls traces and compiles; every call —
    warmup and timed alike — is fenced with ``jax.block_until_ready`` so a
    timed interval can never start while a previous dispatch is still in
    flight, and never end before its own work has."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(call())
    times = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    q1 = times[len(times) // 4]
    q3 = times[min(len(times) - 1, (3 * len(times)) // 4)]
    return {
        "us_median": round(statistics.median(times), 2),
        "us_iqr": round(q3 - q1, 2),
        "reps": int(reps),
        "warmup": int(warmup),
    }


# ---------------------------------------------------------------------------
# Workload synthesis — block-structured sparsity with a KNOWN live fraction
# ---------------------------------------------------------------------------

def _blocky(key, shape: Tuple[int, int], block2: Tuple[int, int],
            live: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(data, block bitmap): iid normal data gated by a Bernoulli(``live``)
    BLOCK mask.  Element-iid zeros almost never kill a whole tile, so the
    block bitmap of such data is ~all-live; gating whole blocks makes the
    measured live fraction equal the drawn bitmap's mean — the workload's
    sparsity is known, not hoped for."""
    from repro.kernels.shapes import ceil_to
    m, n = shape
    b0, b1 = block2
    mb, nb = ceil_to(m, b0) // b0, ceil_to(n, b1) // b1
    kb, kd = jax.random.split(key)
    bm = jax.random.bernoulli(kb, live, (mb, nb))
    expand = jnp.repeat(jnp.repeat(bm, b0, 0), b1, 1)[:m, :n]
    data = jax.random.normal(kd, shape, jnp.float32) * expand
    return data, bm


def cnn_gemm_dims(*, image_size: int, width: float, batch: int,
                  layer: str = "conv2", stage: str = "bp_dx"
                  ) -> Tuple[str, Tuple[int, int, int]]:
    """One (M, K, N) from the CNN's OWN workload description — the dims a
    real training step hands the dispatcher, not invented round numbers."""
    from repro.models.cnn import build_cnn
    model = build_cnn("vgg16", image_size=image_size, width=width,
                      num_classes=10)
    for row in model.gemm_workload(batch):
        if row["layer"] == layer and row["stage"] == stage:
            name = f"cnn:vgg16:{layer}:{stage}"
            return name, (row["m"], row["k"], row["n"])
    raise KeyError(f"{layer}/{stage} not in vgg16 workload")


def bench_gemm_rows(*, smoke: bool) -> List[dict]:
    """One measured row per schedule × workload.  All three schedules run
    the SAME operands and masks; predicated/compact go through the Pallas
    kernels, dense is the xla_ref lowering — so the comparison is the
    paper's §5 scenario sweep at one fixed GEMM."""
    from repro.core import policy as pol
    from repro.kernels import ops
    from repro.kernels.shapes import block_bitmap

    block = (8, 8, 8)
    timing = dict(warmup=1, reps=3) if smoke else dict(warmup=2, reps=5)
    geo = dict(image_size=8, width=0.125, batch=2) if smoke else \
        dict(image_size=10, width=0.25, batch=2)

    cnn_name, cnn_dims = cnn_gemm_dims(**geo)
    ffn_tokens = 64 if smoke else 128
    workloads = [
        (cnn_name, cnn_dims),
        # the down-projection's backward dX GEMM: dL/dh = g @ W_downᵀ with
        # the hidden ReLU mask killing output tiles (paper's core GEMM)
        ("ffn:relu_bwd_dx", (ffn_tokens, 32, 64)),
    ]
    schedule_policies = {
        "predicated": pol.IN_OUT.with_(kernel_impl="pallas", block=block),
        "compact": pol.IN_OUT_WR.with_(kernel_impl="pallas", block=block),
        "dense": pol.IN_OUT,                       # xla_ref ⇒ "dense"
    }

    rows: List[dict] = []
    for wname, (m, k, n) in workloads:
        key = jax.random.key(hash(wname) % (2 ** 31))
        ka, kb_, ko = jax.random.split(key, 3)
        a, _ = _blocky(ka, (m, k), (block[0], block[1]), live=0.6)
        b = jax.random.normal(kb_, (k, n), jnp.float32)
        out_t, _ = _blocky(ko, (m, n), (block[0], block[2]), live=0.5)
        for sched, policy in schedule_policies.items():
            spec = policy.gemm_spec()
            assert spec.schedule == sched, (spec.schedule, sched)
            masks = ops.GemmMasks(
                out=block_bitmap(out_t, spec.block[0], spec.block[2]),
                a=block_bitmap(a, spec.block[0], spec.block[1]),
                b=None)

            fn = jax.jit(functools.partial(
                lambda a_, b_, masks_, spec_: ops.sparse_gemm(
                    a_, b_, masks_, spec_), spec_=spec))
            rows.append({
                "table": "gemm", "workload": wname, "schedule": sched,
                "m": m, "k": k, "n": n, "groups": spec.groups,
                "block": "x".join(map(str, spec.block)),
                **measure(lambda: fn(a, b, masks), **timing),
            })
    return rows


# ---------------------------------------------------------------------------
# Whole train steps
# ---------------------------------------------------------------------------

def _tree_size(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def bench_train_rows(*, smoke: bool) -> List[dict]:
    from repro.core import policy as pol
    from repro.models.cnn import build_cnn
    from repro.models.ffn import FFNConfig, ffn_apply, ffn_init

    timing = dict(warmup=1, reps=3) if smoke else dict(warmup=2, reps=5)
    rows: List[dict] = []
    policy = pol.IN_OUT                           # xla_ref: CPU-feasible

    # -- CNN step --------------------------------------------------------
    batch = 2
    model = build_cnn("vgg16", image_size=8, width=0.125, num_classes=10)
    params = model.init(jax.random.key(0))
    img = jax.random.normal(jax.random.key(1), (batch, 8, 8, 3), jnp.float32)
    lbl = jax.random.randint(jax.random.key(2), (batch,), 0, 10)

    @jax.jit
    def cnn_step(p, img, lbl):
        loss, g = jax.value_and_grad(
            lambda q: model.loss(q, img, lbl, policy))(p)
        return jax.tree.map(lambda w, dw: w - 0.05 * dw, p, g), loss

    rows.append({
        "table": "train_step", "workload": "cnn:vgg16",
        "schedule": policy.gemm_spec().schedule, "batch": batch,
        "params": _tree_size(params),
        **measure(lambda: cnn_step(params, img, lbl), **timing),
    })

    # -- FFN step --------------------------------------------------------
    tokens = 32 if smoke else 64
    cfg = FFNConfig(d_model=16, d_ff=32, activation="relu",
                    sparse_policy=policy)
    fparams = ffn_init(jax.random.key(3), cfg)
    x = jax.random.normal(jax.random.key(4), (tokens, cfg.d_model))
    y = jax.random.normal(jax.random.key(5), (tokens, cfg.d_model))

    @jax.jit
    def ffn_step(p, x, y):
        def loss(q):
            return jnp.mean((ffn_apply(q, x, cfg) - y) ** 2)
        l, g = jax.value_and_grad(loss)(p)
        return jax.tree.map(lambda w, dw: w - 0.05 * dw, p, g), l

    rows.append({
        "table": "train_step", "workload": "ffn:relu",
        "schedule": policy.gemm_spec().schedule, "batch": tokens,
        "params": _tree_size(fparams),
        **measure(lambda: ffn_step(fparams, x, y), **timing),
    })
    return rows


# ---------------------------------------------------------------------------
# Scripted autotune session — the traceability evidence
# ---------------------------------------------------------------------------

def autotune_session(*, drift_steps: Tuple[int, int] = (8, 10),
                     shape_steps: int = 6, seed: int = 0
                     ) -> Tuple[List[dict], List[dict], Dict[str, int]]:
    """Two-part eager session against a FRESH autotune cache; returns
    (per-step selections, decision log, cache counters).

    Part 1 (temporal drift, shapeless key): dispatch at ~25% live output
    tiles — the cache should settle on "compact" — then at 100% live,
    driving a drift retune through "predicated" to "dense" once the
    trailing window is all-dense.

    Part 2 (per-shape keys): two interleaved dims-keyed workloads, one
    staying sparse and one fully dense, must hold DIFFERENT schedules
    simultaneously — the per-(spec, shape) selection the key exists for.

    Eager dispatches only: masks are concrete, so every resolution reads
    MEASURED live fractions recorded by the dispatcher itself."""
    from repro.core import policy as pol
    from repro.kernels import autotune, ops, stats
    from repro.kernels.shapes import block_bitmap

    stats.reset()
    cache = autotune.reset(window=6, min_samples=3)
    block = (8, 8, 8)
    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=block,
                                 autotune=True)
    selections: List[dict] = []
    step = 0

    def dispatch(live: float, dims: Optional[Tuple[int, int, int]],
                 phase: str) -> None:
        nonlocal step
        m, k, n = dims or (32, 16, 24)
        key = jax.random.key(seed * 10_000 + step)
        ka, kb_, ko = jax.random.split(key, 3)
        spec = policy.gemm_spec(dims=dims) if dims is not None \
            else policy.gemm_spec()
        a = jax.random.normal(ka, (m, k), jnp.float32)
        b = jax.random.normal(kb_, (k, n), jnp.float32)
        out_t, _ = _blocky(ko, (m, n), (spec.block[0], spec.block[2]), live)
        masks = ops.GemmMasks(
            out=block_bitmap(out_t, spec.block[0], spec.block[2]))
        ops.sparse_gemm(a, b, masks, spec)        # eager: concrete masks
        selections.append({"step": step, "phase": phase,
                           "live": live, "dims": dims,
                           "schedule": spec.schedule})
        step += 1

    sparse_steps, dense_steps = drift_steps
    for _ in range(sparse_steps):
        dispatch(0.25, None, "drift:sparse")
    for _ in range(dense_steps):
        dispatch(1.0, None, "drift:dense")
    for _ in range(shape_steps):
        dispatch(0.25, (32, 16, 24), "shape:A")
        dispatch(1.0, (16, 16, 16), "shape:B")

    counters = {"hits": cache.hits, "misses": cache.misses,
                "retunes": cache.retunes}
    return selections, autotune.log_rows(), counters


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def check_schema(doc: dict) -> List[str]:
    """Validate a BENCH_7 document; returns a list of problems (empty ⇒
    OK).  Checks the exact per-table key sets, the acceptance coverage
    (every schedule measured for ≥1 CNN and ≥1 FFN GEMM workload; a CNN
    and an FFN train step), positive fenced medians, and that every
    autotune log row carries its traceability fields."""
    errs: List[str] = []
    for top in ("schema_version", "bench", "jax_backend", "geometry",
                "rows", "autotune"):
        if top not in doc:
            errs.append(f"missing top-level key {top!r}")
    if errs:
        return errs
    if doc["schema_version"] != SCHEMA_VERSION:
        errs.append(f"schema_version {doc['schema_version']} != "
                    f"{SCHEMA_VERSION}")

    seen: Dict[str, set] = {"cnn": set(), "ffn": set()}
    train_seen = set()
    for i, row in enumerate(doc["rows"]):
        table = row.get("table")
        if table not in ROW_KEYS:
            errs.append(f"rows[{i}]: unknown table {table!r}")
            continue
        want = set(ROW_KEYS[table])
        got = set(row)
        if got != want:
            errs.append(f"rows[{i}] ({table}): key drift "
                        f"+{sorted(got - want)} -{sorted(want - got)}")
            continue
        if not (isinstance(row["us_median"], (int, float))
                and row["us_median"] > 0):
            errs.append(f"rows[{i}] ({table}): non-positive us_median")
        if table == "gemm":
            if row["schedule"] not in SCHEDULES:
                errs.append(f"rows[{i}]: unknown schedule "
                            f"{row['schedule']!r}")
            fam = row["workload"].split(":", 1)[0]
            if fam in seen:
                seen[fam].add(row["schedule"])
        elif table == "train_step":
            train_seen.add(row["workload"].split(":", 1)[0])

    for fam, scheds in seen.items():
        missing = set(SCHEDULES) - scheds
        if missing:
            errs.append(f"gemm coverage: {fam} workload missing schedules "
                        f"{sorted(missing)}")
    for fam in ("cnn", "ffn"):
        if fam not in train_seen:
            errs.append(f"train_step coverage: no {fam} row")

    at = doc["autotune"]
    for k in ("counters", "selections", "log"):
        if k not in at:
            errs.append(f"autotune: missing {k!r}")
    for i, row in enumerate(at.get("log", [])):
        if set(row) != set(AUTOTUNE_LOG_KEYS):
            errs.append(f"autotune.log[{i}]: key drift {sorted(row)}")
            break
    if not at.get("log"):
        errs.append("autotune.log is empty — selections are not traceable")
    return errs


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_bench(*, smoke: bool = False) -> dict:
    rows = bench_gemm_rows(smoke=smoke) + bench_train_rows(smoke=smoke)
    selections, log, counters = autotune_session()
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": "BENCH_7",
        "jax_backend": jax.default_backend(),
        "geometry": "smoke" if smoke else "full",
        "rows": rows,
        "autotune": {"counters": counters, "selections": selections,
                     "log": log},
    }


def write_outputs(doc: dict, out_path: str) -> None:
    from benchmarks.run import RESULTS_DIR, write_rows
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    by_table: Dict[str, List[dict]] = {}
    for row in doc["rows"]:
        by_table.setdefault(row["table"], []).append(row)
    for table, rows in by_table.items():
        write_rows(os.path.join(RESULTS_DIR, f"wallclock_{table}.csv"), rows)
    if doc["autotune"]["log"]:
        write_rows(os.path.join(RESULTS_DIR, "wallclock_autotune.csv"),
                   doc["autotune"]["log"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced geometry + fewer reps (CI)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="BENCH JSON path (default: repo-root BENCH_7.json)")
    ap.add_argument("--check", metavar="PATH",
                    help="validate an existing BENCH file and exit")
    args = ap.parse_args(argv)

    if args.check:
        with open(args.check) as f:
            errs = check_schema(json.load(f))
        for e in errs:
            print(f"SCHEMA: {e}", file=sys.stderr)
        print(f"{args.check}: {'DRIFT' if errs else 'ok'}")
        return 1 if errs else 0

    doc = run_bench(smoke=args.smoke)
    errs = check_schema(doc)
    if errs:
        for e in errs:
            print(f"SCHEMA: {e}", file=sys.stderr)
        return 1
    write_outputs(doc, args.out)
    for row in doc["rows"]:
        print(f"{row['table']},{row['workload']},{row['schedule']},"
              f"{row['us_median']:.0f}us ±{row['us_iqr']:.0f}")
    c = doc["autotune"]["counters"]
    print(f"autotune: hits={c['hits']} misses={c['misses']} "
          f"retunes={c['retunes']} log_rows={len(doc['autotune']['log'])}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
