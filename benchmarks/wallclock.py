"""Wall-clock truth harness — measured time, honestly bounded.

Every number this module emits follows the same methodology:

  1. the measured callable is jitted and called ``warmup`` times first, so
     trace + compile time is EXCLUDED from every reported figure (the
     ``us_total`` column of benchmarks/run.py deliberately includes it;
     this file is the per-call complement);
  2. every timed call is fenced with ``jax.block_until_ready`` — async
     dispatch means an unfenced ``time.perf_counter`` pair measures queue
     submission, not execution (the same bug class as the per-step
     ``float(metrics["loss"])`` sync that launch/train.py used to have);
  3. the reported figure is the MEDIAN of ``reps`` fenced calls with the
     inter-quartile range as spread — never a single sample, never a mean
     that one scheduler hiccup can poison.

Tables (one CSV each under benchmarks/results/, all rows in BENCH_7.json):

  * ``gemm``        — one ``kernels.ops.sparse_gemm`` dispatch per schedule
                      ∈ {predicated, compact, dense} for one CNN-derived
                      workload (dims from ``CNNModel.gemm_workload``) and
                      one FFN workload (the backward dX GEMM the paper's
                      output sparsity targets);
  * ``train_step``  — one whole jitted train step of models/cnn.py and
                      models/ffn.py (forward + backward + SGD update);
  * ``autotune``    — the decision log of a scripted autotune session
                      (``autotune_session``): sparse→dense drift retunes
                      plus per-(spec, shape) keyed selections, every row
                      traceable to its measured live fraction.

``BENCH_7.json`` at the repo root is schema-stable: ``check_schema``
validates the exact key set per table and the acceptance coverage (every
schedule measured for ≥1 CNN and ≥1 FFN workload); CI runs the smoke
geometry and fails on drift.  See docs/benchmarking.md.

``BENCH_8.json`` is the fused-emit evidence (PR 8): one ``emit`` table
comparing, per backward-dX workload × pallas schedule, the SAME GEMM run
three ways — ``plain`` (no bitmap), ``fused`` (σ′ + ``bitmap_emit`` staged
in the epilogue, one launch returning ``(out, bits)``), and ``gemm_scan``
(σ′ GEMM then a standalone ``kernels.bitmap_scan`` over the output — the
pre-PR-8 two-launch pipeline).  ``check_emit_schema`` validates the key
set, the coverage, and — on full-geometry documents, i.e. the committed
artifact — the headline claim: fused strictly beats GEMM-then-scan on
every (workload, schedule) cell.

``BENCH_9.json`` is the sparsity-on-the-wire evidence (PR 10): one
``collective`` table comparing, per mesh shape × live fraction, the SAME
block-sparse gradient all-reduced two ways inside a ``shard_map`` body —
``dense_psum`` (every block on the wire) and ``bitmap`` (the
``sharding.collectives.sparse_psum`` compressed reduce: psum the tiny
block bitmap, gather/psum only union-live blocks into a static
``ceil(cutoff·nblocks)`` buffer, runtime dense fallback past the
cutoff).  The per-shard block masks are CORRELATED (the same pattern on
every shard) — the dW regime the collective exists for; uncorrelated
masks union to ~dense and honestly take the fallback.
``check_collective_schema`` validates the key set and coverage, and —
on full-geometry documents — the headline claim: bitmap beats dense at
the lowest live fraction on every mesh, and past the cutoff (where the
runtime fallback engages) never loses more than the bitmap-psum
overhead allowance.  BENCH_9 generation is opt-in (``--collective-out``)
so BENCH_7/8-only invocations cannot clobber the committed artifact.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import statistics
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_7.json")
BENCH8_PATH = os.path.join(REPO_ROOT, "BENCH_8.json")
BENCH9_PATH = os.path.join(REPO_ROOT, "BENCH_9.json")

SCHEMA_VERSION = 1
SCHEDULES = ("predicated", "compact", "dense")
EMIT_SCHEDULES = ("predicated", "compact")   # the pallas emit-capable pair
EMIT_VARIANTS = ("plain", "fused", "gemm_scan")
COLLECTIVE_VARIANTS = ("dense_psum", "bitmap")
COLLECTIVE_LIVE_FRACS = (0.05, 0.1, 0.25, 1.0)
# The bench cutoff is deliberately tight (capacity = 1/8 of the blocks):
# on a shared-memory CPU "mesh" the wire IS the memory bus, so the
# compressed path's local gather/scatter copies cost the same per byte as
# the psum they save — compression only wins when capacity + overhead
# stays well under the dense volume.  A real interconnect (wire ≫ memory)
# widens the win and would justify the looser training default
# (``sharding.spmd_step.DEFAULT_CUTOFF``).
COLLECTIVE_CUTOFF = 0.125
# Fallback rows (live_frac > cutoff) may not beat dense — they ARE dense
# plus a tiny bitmap psum + branch; allow that overhead, bounded.
COLLECTIVE_FALLBACK_SLACK = 1.25

# The exact per-table row key sets the BENCH files commit to.  The schema
# checkers fail on ANY deviation — added keys are drift just like missing.
ROW_KEYS = {
    "gemm": ("table", "workload", "schedule", "m", "k", "n", "groups",
             "block", "us_median", "us_iqr", "reps", "warmup"),
    "train_step": ("table", "workload", "schedule", "batch", "params",
                   "us_median", "us_iqr", "reps", "warmup"),
    "emit": ("table", "workload", "schedule", "variant", "m", "k", "n",
             "groups", "block", "emit_gran", "us_median", "us_iqr",
             "reps", "warmup"),
    "collective": ("table", "mesh", "devices", "m", "n", "block",
                   "live_frac", "cutoff", "variant", "us_median", "us_iqr",
                   "reps", "warmup"),
}
AUTOTUNE_LOG_KEYS = ("seq", "event", "key", "shape", "groups", "schedule",
                     "block", "live_frac", "operand_frac", "samples")


# ---------------------------------------------------------------------------
# The one timing primitive
# ---------------------------------------------------------------------------

def measure(call: Callable[[], object], *, warmup: int = 2,
            reps: int = 5) -> Dict[str, float]:
    """Median-of-``reps`` fenced wall time of ``call`` in µs, compile
    excluded.

    ``call`` must return its device output (a jitted function application):
    the first of the ``warmup`` calls traces and compiles; every call —
    warmup and timed alike — is fenced with ``jax.block_until_ready`` so a
    timed interval can never start while a previous dispatch is still in
    flight, and never end before its own work has."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(call())
    times = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    q1 = times[len(times) // 4]
    q3 = times[min(len(times) - 1, (3 * len(times)) // 4)]
    return {
        "us_median": round(statistics.median(times), 2),
        "us_iqr": round(q3 - q1, 2),
        "reps": int(reps),
        "warmup": int(warmup),
    }


# ---------------------------------------------------------------------------
# Workload synthesis — block-structured sparsity with a KNOWN live fraction
# ---------------------------------------------------------------------------

def _blocky(key, shape: Tuple[int, int], block2: Tuple[int, int],
            live: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(data, block bitmap): iid normal data gated by a Bernoulli(``live``)
    BLOCK mask.  Element-iid zeros almost never kill a whole tile, so the
    block bitmap of such data is ~all-live; gating whole blocks makes the
    measured live fraction equal the drawn bitmap's mean — the workload's
    sparsity is known, not hoped for."""
    from repro.kernels.shapes import ceil_to
    m, n = shape
    b0, b1 = block2
    mb, nb = ceil_to(m, b0) // b0, ceil_to(n, b1) // b1
    kb, kd = jax.random.split(key)
    bm = jax.random.bernoulli(kb, live, (mb, nb))
    expand = jnp.repeat(jnp.repeat(bm, b0, 0), b1, 1)[:m, :n]
    data = jax.random.normal(kd, shape, jnp.float32) * expand
    return data, bm


def cnn_gemm_dims(*, image_size: int, width: float, batch: int,
                  layer: str = "conv2", stage: str = "bp_dx",
                  net: str = "vgg16") -> Tuple[str, Tuple[int, int, int]]:
    """One (M, K, N) from the CNN's OWN workload description — the dims a
    real training step hands the dispatcher, not invented round numbers."""
    from repro.models.cnn import build_cnn
    model = build_cnn(net, image_size=image_size, width=width,
                      num_classes=10)
    for row in model.gemm_workload(batch):
        if row["layer"] == layer and row["stage"] == stage:
            name = f"cnn:{net}:{layer}:{stage}"
            return name, (row["m"], row["k"], row["n"])
    raise KeyError(f"{layer}/{stage} not in {net} workload")


def bench_gemm_rows(*, smoke: bool) -> List[dict]:
    """One measured row per schedule × workload.  All three schedules run
    the SAME operands and masks; predicated/compact go through the Pallas
    kernels, dense is the xla_ref lowering — so the comparison is the
    paper's §5 scenario sweep at one fixed GEMM."""
    from repro.core import policy as pol
    from repro.kernels import ops
    from repro.kernels.shapes import block_bitmap

    block = (8, 8, 8)
    timing = dict(warmup=1, reps=3) if smoke else dict(warmup=2, reps=5)
    geo = dict(image_size=8, width=0.125, batch=2) if smoke else \
        dict(image_size=10, width=0.25, batch=2)

    cnn_name, cnn_dims = cnn_gemm_dims(**geo)
    ffn_tokens = 64 if smoke else 128
    workloads = [
        (cnn_name, cnn_dims),
        # the down-projection's backward dX GEMM: dL/dh = g @ W_downᵀ with
        # the hidden ReLU mask killing output tiles (paper's core GEMM)
        ("ffn:relu_bwd_dx", (ffn_tokens, 32, 64)),
    ]
    schedule_policies = {
        "predicated": pol.IN_OUT.with_(kernel_impl="pallas", block=block),
        "compact": pol.IN_OUT_WR.with_(kernel_impl="pallas", block=block),
        "dense": pol.IN_OUT,                       # xla_ref ⇒ "dense"
    }

    rows: List[dict] = []
    for wname, (m, k, n) in workloads:
        key = jax.random.key(hash(wname) % (2 ** 31))
        ka, kb_, ko = jax.random.split(key, 3)
        a, _ = _blocky(ka, (m, k), (block[0], block[1]), live=0.6)
        b = jax.random.normal(kb_, (k, n), jnp.float32)
        out_t, _ = _blocky(ko, (m, n), (block[0], block[2]), live=0.5)
        for sched, policy in schedule_policies.items():
            spec = policy.gemm_spec()
            assert spec.schedule == sched, (spec.schedule, sched)
            masks = ops.GemmMasks(
                out=block_bitmap(out_t, spec.block[0], spec.block[2]),
                a=block_bitmap(a, spec.block[0], spec.block[1]),
                b=None)

            fn = jax.jit(functools.partial(
                lambda a_, b_, masks_, spec_: ops.sparse_gemm(
                    a_, b_, masks_, spec_), spec_=spec))
            rows.append({
                "table": "gemm", "workload": wname, "schedule": sched,
                "m": m, "k": k, "n": n, "groups": spec.groups,
                "block": "x".join(map(str, spec.block)),
                **measure(lambda: fn(a, b, masks), **timing),
            })
    return rows


# ---------------------------------------------------------------------------
# Fused bitmap emission vs GEMM-then-scan (the BENCH_8 evidence)
# ---------------------------------------------------------------------------

def bench_emit_rows(*, smoke: bool) -> List[dict]:
    """One measured row per workload × pallas schedule × variant.

    The workload is the paper's hot GEMM — backward dX (``dy @ Wᵀ``) with
    the σ′ mask killing output tiles — and the variants are the same GEMM
    run three ways on identical operands and masks:

      * ``plain``      σ′ epilogue only (no bitmap anywhere) — the floor;
      * ``fused``      σ′ + ``bitmap_emit`` staged in the epilogue: ONE
                       launch returns ``(out, bits)``, thresholding each
                       accumulator tile at writeback;
      * ``gemm_scan``  σ′ GEMM, then a standalone ``kernels.bitmap_scan``
                       re-reads the output — the pre-PR-8 pipeline this
                       epilogue deletes from the training hot path.

    The committed (full-geometry) BENCH_8.json must show fused < gemm_scan
    on every cell (``check_emit_schema`` enforces it).

    Workload choice: the structural advantage of the emit epilogue is that
    it runs only on LIVE output tiles inside the producing launch, while
    the standalone scan re-reads EVERY tile of the output — so the honest
    showcase is the paper's sparse-dy regime (25% live σ′ tiles) on
    backward-dX geometries whose output is large relative to the reduction
    axis: a MobileNet pointwise conv's dX (K = Cout of a 1×1 kernel) and
    an FFN down-projection's dX.  The compact schedule is bounded to the
    drawn live-tile count (the WDU capacity a trained step would carry)."""
    import numpy as np

    from repro.core import policy as pol
    from repro.kernels import ops
    from repro.kernels.shapes import block_bitmap

    block = (8, 32, 8)
    emit_gran = (block[0], block[2])
    live = 0.25
    timing = dict(warmup=1, reps=3) if smoke else dict(warmup=2, reps=9)
    geo = dict(image_size=32, width=0.5, batch=2 if smoke else 8,
               layer="pw1", net="mobilenet")

    cnn_name, cnn_dims = cnn_gemm_dims(**geo)
    ffn_tokens = 256 if smoke else 1024
    workloads = [
        (cnn_name, cnn_dims),
        # the down-projection's backward dX GEMM: dL/dh = g @ W_downᵀ with
        # the hidden ReLU mask killing output tiles (paper's core GEMM)
        ("ffn:relu_bwd_dx", (ffn_tokens, 32, 64)),
    ]
    schedule_policies = {
        "predicated": pol.IN_OUT.with_(kernel_impl="pallas", block=block),
        "compact": pol.IN_OUT_WR.with_(kernel_impl="pallas", block=block),
    }

    rows: List[dict] = []
    for wname, (m, k, n) in workloads:
        key = jax.random.key(hash(("emit", wname)) % (2 ** 31))
        ka, kb_, km = jax.random.split(key, 3)
        dy = jax.random.normal(ka, (m, k), jnp.float32)
        wt = jax.random.normal(kb_, (k, n), jnp.float32)
        # σ′ footprint: block-structured so the out mask has dead tiles
        _, mult_bm = _blocky(km, (m, n), (block[0], block[2]), live)
        mult = jnp.repeat(jnp.repeat(mult_bm, block[0], 0),
                          block[2], 1)[:m, :n].astype(jnp.float32)
        n_live = int(np.asarray(mult_bm).sum())
        for sched, policy in schedule_policies.items():
            base = policy.gemm_spec()
            assert base.schedule == sched, (base.schedule, sched)
            if sched == "compact":
                base = base.with_(max_active_blocks=n_live)
            masks = ops.GemmMasks(out=block_bitmap(mult, block[0], block[2]))
            spec_p = base.with_(epilogue=("sigma_prime",))
            spec_f = base.with_(epilogue=("sigma_prime", "bitmap_emit"),
                                emit_gran=emit_gran)

            def plain(a_, b_, masks_, mult_):
                return ops.sparse_gemm(a_, b_, masks_, spec_p,
                                       epilogue_mult=mult_)

            def fused(a_, b_, masks_, mult_):
                return ops.sparse_gemm(a_, b_, masks_, spec_f,
                                       epilogue_mult=mult_)

            def gemm_scan(a_, b_, masks_, mult_):
                out = ops.sparse_gemm(a_, b_, masks_, spec_p,
                                      epilogue_mult=mult_)
                return out, ops.bitmap_scan(out, block=emit_gran,
                                            kind="grad")

            for variant, fn in (("plain", plain), ("fused", fused),
                                ("gemm_scan", gemm_scan)):
                jfn = jax.jit(fn)
                rows.append({
                    "table": "emit", "workload": wname, "schedule": sched,
                    "variant": variant, "m": m, "k": k, "n": n,
                    "groups": base.groups,
                    "block": "x".join(map(str, block)),
                    "emit_gran": "x".join(map(str, emit_gran)),
                    **measure(lambda: jfn(dy, wt, masks, mult), **timing),
                })
    return rows


# ---------------------------------------------------------------------------
# Bitmap-compressed all-reduce vs dense psum (the BENCH_9 evidence)
# ---------------------------------------------------------------------------

def _collective_meshes() -> List[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    """Mesh shapes the collective table sweeps, derived from the devices
    actually visible: always the flat data mesh, plus a 2-D (data, pod)
    factoring when the device count supports it — the compressed reduce
    must not regress when the psum spans more than one mesh axis."""
    n_dev = jax.device_count()
    meshes = [((n_dev,), ("data",))]
    if n_dev >= 4 and n_dev % 2 == 0:
        meshes.append(((2, n_dev // 2), ("data", "pod")))
    return meshes


def bench_collective_rows(*, smoke: bool) -> List[dict]:
    """One measured row per mesh × live fraction × variant.

    Both variants all-reduce the SAME (devices, M, N) block-sparse
    gradient stack inside a jitted ``shard_map`` body; ``dense_psum`` is
    the uncompressed baseline, ``bitmap`` is ``sparse_psum`` fed the
    shard-local block bitmap (gran == wire block, so no coarsening is
    timed — the lifecycle already owns derivation).

    Workload construction is the honest part:

      * the live blocks are drawn ONCE per (mesh, live) cell and repeated
        on every shard — dW gradients in data-parallel training share
        sparsity structure across shards (same weights, same σ′
        geometry), and that correlation is what keeps the union small
        (uncorrelated shard masks union to ~dense and take the fallback);
      * the sparsity is ROW-BLOCK structured and the wire block spans the
        full row — the paper's regime: a feature whose activation the
        ReLU killed across the whole batch zeroes the entire dW row, so
        whole row-blocks go dead together.  Full-width wire blocks also
        keep the compact gather/scatter contiguous (each block one
        memcpy), which on a shared-memory CPU mesh is the difference
        between compression winning and drowning in strided-gather cost;
      * the live count is exact (a permutation draw, not a Bernoulli
        hope), so ``live_frac`` in each row is the workload's true wire
        live fraction and the cutoff comparison is sharp:
        ``live_frac ≤ cutoff`` rows exercise the compressed path,
        ``live_frac > cutoff`` rows the runtime dense fallback.

    ``sparse_psum`` is fed the FINE (gran-level) bitmap and told the
    wire block, so the timed path includes the gran→wire coarsening the
    lifecycle mandates (derivation, never a rescan)."""
    from repro.kernels import stats

    # The fallback/compressed runtime counters are host callbacks — per
    # execution, per shard.  They are audit instrumentation, not the
    # collective; staged into a timed trace they'd dominate the medians.
    prev_counting = stats.set_runtime_counting(False)
    try:
        return _collective_rows_inner(smoke=smoke)
    finally:
        stats.set_runtime_counting(prev_counting)


def _collective_rows_inner(*, smoke: bool) -> List[dict]:
    import numpy as np

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.collectives import dense_psum, sparse_psum

    n_dev = jax.device_count()
    b0 = 32 if smoke else 128            # row-block height; wire = (b0, N)
    m, n = (512, 256) if smoke else (8192, 2048)
    gran = (b0, b0)                      # the fine bitmap's granularity
    timing = dict(warmup=1, reps=3) if smoke else dict(warmup=2, reps=7)
    mt, nt_g = m // b0, n // b0          # fine-bitmap grid; wire nblk = mt

    rows: List[dict] = []
    for shape, names in _collective_meshes():
        mesh = jax.make_mesh(shape, names)
        spec_in = P(tuple(names))       # dim 0 sharded over every axis
        for live in COLLECTIVE_LIVE_FRACS:
            rng = np.random.default_rng(hash((shape, live)) % (2 ** 31))
            count = max(1, min(mt, round(live * mt)))
            row_live = np.zeros(mt, np.int32)
            row_live[rng.permutation(mt)[:count]] = 1
            expand = np.repeat(row_live, b0).astype(np.float32)[:, None]
            data = (rng.standard_normal((n_dev, m, n)).astype(np.float32)
                    * expand[None])
            bm = np.repeat(row_live[:, None], nt_g, 1)
            xs = jnp.asarray(data)
            bs = jnp.asarray(np.broadcast_to(bm, (n_dev, mt, nt_g)).copy())

            def body_dense(x, b):
                return dense_psum(x[0], axis_name=names)

            def body_bitmap(x, b):
                return sparse_psum(x[0], b[0], gran, axis_name=names,
                                   block=(b0, n),
                                   cutoff=COLLECTIVE_CUTOFF)

            for variant, body in (("dense_psum", body_dense),
                                  ("bitmap", body_bitmap)):
                fn = jax.jit(shard_map(
                    body, mesh=mesh, in_specs=(spec_in, spec_in),
                    out_specs=P(), check_rep=False))
                rows.append({
                    "table": "collective",
                    "mesh": "x".join(map(str, shape)),
                    "devices": n_dev, "m": m, "n": n,
                    "block": f"{b0}x{n}",
                    "live_frac": live, "cutoff": COLLECTIVE_CUTOFF,
                    "variant": variant,
                    **measure(lambda: fn(xs, bs), **timing),
                })
            del data, xs, bs
    return rows


# ---------------------------------------------------------------------------
# Whole train steps
# ---------------------------------------------------------------------------

def _tree_size(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def bench_train_rows(*, smoke: bool) -> List[dict]:
    from repro.core import policy as pol
    from repro.models.cnn import build_cnn
    from repro.models.ffn import FFNConfig, ffn_apply, ffn_init

    timing = dict(warmup=1, reps=3) if smoke else dict(warmup=2, reps=5)
    rows: List[dict] = []
    policy = pol.IN_OUT                           # xla_ref: CPU-feasible

    # -- CNN step --------------------------------------------------------
    batch = 2
    model = build_cnn("vgg16", image_size=8, width=0.125, num_classes=10)
    params = model.init(jax.random.key(0))
    img = jax.random.normal(jax.random.key(1), (batch, 8, 8, 3), jnp.float32)
    lbl = jax.random.randint(jax.random.key(2), (batch,), 0, 10)

    @jax.jit
    def cnn_step(p, img, lbl):
        loss, g = jax.value_and_grad(
            lambda q: model.loss(q, img, lbl, policy))(p)
        return jax.tree.map(lambda w, dw: w - 0.05 * dw, p, g), loss

    rows.append({
        "table": "train_step", "workload": "cnn:vgg16",
        "schedule": policy.gemm_spec().schedule, "batch": batch,
        "params": _tree_size(params),
        **measure(lambda: cnn_step(params, img, lbl), **timing),
    })

    # -- FFN step --------------------------------------------------------
    tokens = 32 if smoke else 64
    cfg = FFNConfig(d_model=16, d_ff=32, activation="relu",
                    sparse_policy=policy)
    fparams = ffn_init(jax.random.key(3), cfg)
    x = jax.random.normal(jax.random.key(4), (tokens, cfg.d_model))
    y = jax.random.normal(jax.random.key(5), (tokens, cfg.d_model))

    @jax.jit
    def ffn_step(p, x, y):
        def loss(q):
            return jnp.mean((ffn_apply(q, x, cfg) - y) ** 2)
        l, g = jax.value_and_grad(loss)(p)
        return jax.tree.map(lambda w, dw: w - 0.05 * dw, p, g), l

    rows.append({
        "table": "train_step", "workload": "ffn:relu",
        "schedule": policy.gemm_spec().schedule, "batch": tokens,
        "params": _tree_size(fparams),
        **measure(lambda: ffn_step(fparams, x, y), **timing),
    })
    return rows


# ---------------------------------------------------------------------------
# Scripted autotune session — the traceability evidence
# ---------------------------------------------------------------------------

def autotune_session(*, drift_steps: Tuple[int, int] = (8, 10),
                     shape_steps: int = 6, seed: int = 0
                     ) -> Tuple[List[dict], List[dict], Dict[str, int]]:
    """Two-part eager session against a FRESH autotune cache; returns
    (per-step selections, decision log, cache counters).

    Part 1 (temporal drift, shapeless key): dispatch at ~25% live output
    tiles — the cache should settle on "compact" — then at 100% live,
    driving a drift retune through "predicated" to "dense" once the
    trailing window is all-dense.

    Part 2 (per-shape keys): two interleaved dims-keyed workloads, one
    staying sparse and one fully dense, must hold DIFFERENT schedules
    simultaneously — the per-(spec, shape) selection the key exists for.

    Eager dispatches only: masks are concrete, so every resolution reads
    MEASURED live fractions recorded by the dispatcher itself."""
    from repro.core import policy as pol
    from repro.kernels import autotune, ops, stats
    from repro.kernels.shapes import block_bitmap

    stats.reset()
    cache = autotune.reset(window=6, min_samples=3)
    block = (8, 8, 8)
    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=block,
                                 autotune=True)
    selections: List[dict] = []
    step = 0

    def dispatch(live: float, dims: Optional[Tuple[int, int, int]],
                 phase: str) -> None:
        nonlocal step
        m, k, n = dims or (32, 16, 24)
        key = jax.random.key(seed * 10_000 + step)
        ka, kb_, ko = jax.random.split(key, 3)
        spec = policy.gemm_spec(dims=dims) if dims is not None \
            else policy.gemm_spec()
        a = jax.random.normal(ka, (m, k), jnp.float32)
        b = jax.random.normal(kb_, (k, n), jnp.float32)
        out_t, _ = _blocky(ko, (m, n), (spec.block[0], spec.block[2]), live)
        masks = ops.GemmMasks(
            out=block_bitmap(out_t, spec.block[0], spec.block[2]))
        ops.sparse_gemm(a, b, masks, spec)        # eager: concrete masks
        selections.append({"step": step, "phase": phase,
                           "live": live, "dims": dims,
                           "schedule": spec.schedule})
        step += 1

    sparse_steps, dense_steps = drift_steps
    for _ in range(sparse_steps):
        dispatch(0.25, None, "drift:sparse")
    for _ in range(dense_steps):
        dispatch(1.0, None, "drift:dense")
    for _ in range(shape_steps):
        dispatch(0.25, (32, 16, 24), "shape:A")
        dispatch(1.0, (16, 16, 16), "shape:B")

    counters = {"hits": cache.hits, "misses": cache.misses,
                "retunes": cache.retunes}
    return selections, autotune.log_rows(), counters


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def check_schema(doc: dict) -> List[str]:
    """Validate a BENCH_7 document; returns a list of problems (empty ⇒
    OK).  Checks the exact per-table key sets, the acceptance coverage
    (every schedule measured for ≥1 CNN and ≥1 FFN GEMM workload; a CNN
    and an FFN train step), positive fenced medians, and that every
    autotune log row carries its traceability fields."""
    errs: List[str] = []
    for top in ("schema_version", "bench", "jax_backend", "geometry",
                "rows", "autotune"):
        if top not in doc:
            errs.append(f"missing top-level key {top!r}")
    if errs:
        return errs
    if doc["schema_version"] != SCHEMA_VERSION:
        errs.append(f"schema_version {doc['schema_version']} != "
                    f"{SCHEMA_VERSION}")

    seen: Dict[str, set] = {"cnn": set(), "ffn": set()}
    train_seen = set()
    for i, row in enumerate(doc["rows"]):
        table = row.get("table")
        if table not in ROW_KEYS:
            errs.append(f"rows[{i}]: unknown table {table!r}")
            continue
        want = set(ROW_KEYS[table])
        got = set(row)
        if got != want:
            errs.append(f"rows[{i}] ({table}): key drift "
                        f"+{sorted(got - want)} -{sorted(want - got)}")
            continue
        if not (isinstance(row["us_median"], (int, float))
                and row["us_median"] > 0):
            errs.append(f"rows[{i}] ({table}): non-positive us_median")
        if table == "gemm":
            if row["schedule"] not in SCHEDULES:
                errs.append(f"rows[{i}]: unknown schedule "
                            f"{row['schedule']!r}")
            fam = row["workload"].split(":", 1)[0]
            if fam in seen:
                seen[fam].add(row["schedule"])
        elif table == "train_step":
            train_seen.add(row["workload"].split(":", 1)[0])

    for fam, scheds in seen.items():
        missing = set(SCHEDULES) - scheds
        if missing:
            errs.append(f"gemm coverage: {fam} workload missing schedules "
                        f"{sorted(missing)}")
    for fam in ("cnn", "ffn"):
        if fam not in train_seen:
            errs.append(f"train_step coverage: no {fam} row")

    at = doc["autotune"]
    for k in ("counters", "selections", "log"):
        if k not in at:
            errs.append(f"autotune: missing {k!r}")
    for i, row in enumerate(at.get("log", [])):
        if set(row) != set(AUTOTUNE_LOG_KEYS):
            errs.append(f"autotune.log[{i}]: key drift {sorted(row)}")
            break
    if not at.get("log"):
        errs.append("autotune.log is empty — selections are not traceable")
    return errs


def check_emit_schema(doc: dict) -> List[str]:
    """Validate a BENCH_8 document; returns a list of problems (empty ⇒
    OK).  Checks the exact ``emit`` row key set, the coverage (every
    variant measured for both pallas schedules on ≥1 CNN and ≥1 FFN
    backward-dX workload), positive fenced medians, AND — on
    full-geometry documents (the committed artifact) — the headline
    claim: fused σ′+emit strictly beats GEMM-then-scan on every cell.
    Smoke documents skip only the claim: reduced reps on shared CI
    runners make a strict wall-clock inequality a coin-flip; the
    committed full-geometry run is the evidence the PR stands on."""
    errs: List[str] = []
    for top in ("schema_version", "bench", "jax_backend", "geometry",
                "rows"):
        if top not in doc:
            errs.append(f"missing top-level key {top!r}")
    if errs:
        return errs
    if doc["schema_version"] != SCHEMA_VERSION:
        errs.append(f"schema_version {doc['schema_version']} != "
                    f"{SCHEMA_VERSION}")
    if doc["bench"] != "BENCH_8":
        errs.append(f"bench {doc['bench']!r} != 'BENCH_8'")

    want = set(ROW_KEYS["emit"])
    cells: Dict[Tuple[str, str], Dict[str, float]] = {}
    seen: Dict[str, set] = {"cnn": set(), "ffn": set()}
    for i, row in enumerate(doc["rows"]):
        if row.get("table") != "emit":
            errs.append(f"rows[{i}]: unknown table {row.get('table')!r}")
            continue
        got = set(row)
        if got != want:
            errs.append(f"rows[{i}] (emit): key drift "
                        f"+{sorted(got - want)} -{sorted(want - got)}")
            continue
        if row["schedule"] not in EMIT_SCHEDULES:
            errs.append(f"rows[{i}]: unknown schedule {row['schedule']!r}")
        if row["variant"] not in EMIT_VARIANTS:
            errs.append(f"rows[{i}]: unknown variant {row['variant']!r}")
            continue
        if not (isinstance(row["us_median"], (int, float))
                and row["us_median"] > 0):
            errs.append(f"rows[{i}] (emit): non-positive us_median")
            continue
        fam = row["workload"].split(":", 1)[0]
        if fam in seen:
            seen[fam].add((row["schedule"], row["variant"]))
        cells.setdefault((row["workload"], row["schedule"]), {})[
            row["variant"]] = row["us_median"]

    full = {(s, v) for s in EMIT_SCHEDULES for v in EMIT_VARIANTS}
    for fam, got in seen.items():
        missing = sorted(full - got)
        if missing:
            errs.append(f"emit coverage: {fam} workload missing cells "
                        f"{missing}")

    if doc.get("geometry") != "full":
        return errs                       # claim gated on committed runs
    for (wname, sched), by_variant in sorted(cells.items()):
        if set(by_variant) != set(EMIT_VARIANTS):
            continue                      # coverage error already reported
        if not by_variant["fused"] < by_variant["gemm_scan"]:
            errs.append(
                f"claim: fused ({by_variant['fused']}us) not faster than "
                f"gemm_scan ({by_variant['gemm_scan']}us) on "
                f"{wname}/{sched} — the emit epilogue must beat the "
                f"two-launch pipeline")
    return errs


def check_collective_schema(doc: dict) -> List[str]:
    """Validate a BENCH_9 document; returns a list of problems (empty ⇒
    OK).  Checks the exact ``collective`` row key set, the coverage (both
    variants measured for every live fraction on ≥1 mesh, and every mesh
    covering the full live-fraction sweep), positive fenced medians, AND
    — on full-geometry documents (the committed artifact) — the headline
    claim: the bitmap-compressed reduce strictly beats the dense psum at
    the LOWEST live fraction on every mesh, and on past-cutoff rows
    (where ``sparse_psum`` runtime-falls-back to dense) costs at most
    ``COLLECTIVE_FALLBACK_SLACK``× dense — the fallback means the
    compressed path never loses more than its tiny bitmap-psum + branch
    overhead.  Smoke documents skip only the claim (reduced reps on
    shared CI runners make a strict wall-clock inequality a coin-flip)."""
    errs: List[str] = []
    for top in ("schema_version", "bench", "jax_backend", "geometry",
                "rows"):
        if top not in doc:
            errs.append(f"missing top-level key {top!r}")
    if errs:
        return errs
    if doc["schema_version"] != SCHEMA_VERSION:
        errs.append(f"schema_version {doc['schema_version']} != "
                    f"{SCHEMA_VERSION}")
    if doc["bench"] != "BENCH_9":
        errs.append(f"bench {doc['bench']!r} != 'BENCH_9'")

    want = set(ROW_KEYS["collective"])
    cells: Dict[Tuple[str, float], Dict[str, float]] = {}
    cutoffs: Dict[str, float] = {}
    for i, row in enumerate(doc["rows"]):
        if row.get("table") != "collective":
            errs.append(f"rows[{i}]: unknown table {row.get('table')!r}")
            continue
        got = set(row)
        if got != want:
            errs.append(f"rows[{i}] (collective): key drift "
                        f"+{sorted(got - want)} -{sorted(want - got)}")
            continue
        if row["variant"] not in COLLECTIVE_VARIANTS:
            errs.append(f"rows[{i}]: unknown variant {row['variant']!r}")
            continue
        if not (isinstance(row["us_median"], (int, float))
                and row["us_median"] > 0):
            errs.append(f"rows[{i}] (collective): non-positive us_median")
            continue
        cells.setdefault((row["mesh"], row["live_frac"]), {})[
            row["variant"]] = row["us_median"]
        cutoffs[row["mesh"]] = row["cutoff"]

    if not cells:
        errs.append("collective coverage: no rows")
        return errs
    by_mesh: Dict[str, set] = {}
    for (mesh_name, live), by_variant in cells.items():
        by_mesh.setdefault(mesh_name, set()).add(live)
        missing = sorted(set(COLLECTIVE_VARIANTS) - set(by_variant))
        if missing:
            errs.append(f"collective coverage: {mesh_name}@{live} missing "
                        f"variants {missing}")
    for mesh_name, lives in by_mesh.items():
        missing = sorted(set(COLLECTIVE_LIVE_FRACS) - lives)
        if missing:
            errs.append(f"collective coverage: {mesh_name} missing live "
                        f"fractions {missing}")

    if doc.get("geometry") != "full":
        return errs                       # claim gated on committed runs
    for mesh_name, lives in sorted(by_mesh.items()):
        cutoff = cutoffs[mesh_name]
        lowest = min(lives)
        for live in sorted(lives):
            by_variant = cells[(mesh_name, live)]
            if set(by_variant) != set(COLLECTIVE_VARIANTS):
                continue                  # coverage error already reported
            bm, dn = by_variant["bitmap"], by_variant["dense_psum"]
            if live == lowest and not bm < dn:
                errs.append(
                    f"claim: bitmap ({bm}us) not faster than dense_psum "
                    f"({dn}us) on {mesh_name}@{live} — the compressed "
                    f"reduce must win where the union is sparse")
            if live > cutoff and not bm <= dn * COLLECTIVE_FALLBACK_SLACK:
                errs.append(
                    f"claim: bitmap ({bm}us) > {COLLECTIVE_FALLBACK_SLACK}x "
                    f"dense_psum ({dn}us) on {mesh_name}@{live} — past the "
                    f"cutoff the runtime fallback must keep the compressed "
                    f"path from losing")
    return errs


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_bench(*, smoke: bool = False) -> dict:
    rows = bench_gemm_rows(smoke=smoke) + bench_train_rows(smoke=smoke)
    selections, log, counters = autotune_session()
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": "BENCH_7",
        "jax_backend": jax.default_backend(),
        "geometry": "smoke" if smoke else "full",
        "rows": rows,
        "autotune": {"counters": counters, "selections": selections,
                     "log": log},
    }


def run_emit_bench(*, smoke: bool = False) -> dict:
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": "BENCH_8",
        "jax_backend": jax.default_backend(),
        "geometry": "smoke" if smoke else "full",
        "rows": bench_emit_rows(smoke=smoke),
    }


def run_collective_bench(*, smoke: bool = False) -> dict:
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": "BENCH_9",
        "jax_backend": jax.default_backend(),
        "geometry": "smoke" if smoke else "full",
        "rows": bench_collective_rows(smoke=smoke),
    }


def write_outputs(doc: dict, out_path: str) -> None:
    from benchmarks.run import RESULTS_DIR, write_rows
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    by_table: Dict[str, List[dict]] = {}
    for row in doc["rows"]:
        by_table.setdefault(row["table"], []).append(row)
    for table, rows in by_table.items():
        write_rows(os.path.join(RESULTS_DIR, f"wallclock_{table}.csv"), rows)
    if doc.get("autotune", {}).get("log"):
        write_rows(os.path.join(RESULTS_DIR, "wallclock_autotune.csv"),
                   doc["autotune"]["log"])


def _checker_for(doc: dict):
    return {"BENCH_8": check_emit_schema,
            "BENCH_9": check_collective_schema}.get(
                doc.get("bench"), check_schema)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced geometry + fewer reps (CI)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="BENCH JSON path (default: repo-root BENCH_7.json)")
    ap.add_argument("--emit-out", default=BENCH8_PATH,
                    help="BENCH_8 (emit table) JSON path (default: "
                         "repo-root BENCH_8.json)")
    ap.add_argument("--collective-out", nargs="?", const=BENCH9_PATH,
                    default=None, metavar="PATH",
                    help="ALSO generate the BENCH_9 (collective table) "
                         "document at PATH (default when the flag is bare: "
                         "repo-root BENCH_9.json).  Opt-in: without this "
                         "flag BENCH_9 is never written, so BENCH_7/8 "
                         "regenerations cannot clobber the committed "
                         "artifact")
    ap.add_argument("--collective-only", action="store_true",
                    help="generate ONLY the BENCH_9 document (skip "
                         "BENCH_7/8) — the sharded-smoke CI job's mode")
    ap.add_argument("--check", metavar="PATH",
                    help="validate an existing BENCH file and exit "
                         "(the checker is picked by the file's 'bench' key)")
    args = ap.parse_args(argv)

    if args.check:
        with open(args.check) as f:
            doc = json.load(f)
        errs = _checker_for(doc)(doc)
        for e in errs:
            print(f"SCHEMA: {e}", file=sys.stderr)
        print(f"{args.check}: {'DRIFT' if errs else 'ok'}")
        return 1 if errs else 0

    collective_out = args.collective_out
    if args.collective_only and collective_out is None:
        collective_out = BENCH9_PATH

    outputs: List[Tuple[dict, str]] = []
    if not args.collective_only:
        outputs.append((run_bench(smoke=args.smoke), args.out))
        outputs.append((run_emit_bench(smoke=args.smoke), args.emit_out))
    if collective_out is not None:
        outputs.append((run_collective_bench(smoke=args.smoke),
                        collective_out))

    errs = [e for doc, _ in outputs for e in _checker_for(doc)(doc)]
    if errs:
        for e in errs:
            print(f"SCHEMA: {e}", file=sys.stderr)
        return 1
    for doc, path in outputs:
        write_outputs(doc, path)
    for doc, _ in outputs:
        for row in doc["rows"]:
            if row["table"] == "collective":
                print(f"collective,{row['mesh']},live={row['live_frac']},"
                      f"{row['variant']},{row['us_median']:.0f}us "
                      f"±{row['us_iqr']:.0f}")
            else:
                tag = f":{row['variant']}" if row["table"] == "emit" else ""
                print(f"{row['table']},{row['workload']},"
                      f"{row['schedule']}{tag},"
                      f"{row['us_median']:.0f}us ±{row['us_iqr']:.0f}")
        if "autotune" in doc:
            c = doc["autotune"]["counters"]
            print(f"autotune: hits={c['hits']} misses={c['misses']} "
                  f"retunes={c['retunes']} "
                  f"log_rows={len(doc['autotune']['log'])}")
    print("wrote " + " and ".join(path for _, path in outputs))
    return 0


if __name__ == "__main__":
    sys.exit(main())
