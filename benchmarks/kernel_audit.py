"""Kernel audit: block-skip capture rate on real traces + structure sweep.

THE key hardware-adaptation question (DESIGN.md §2): how much of the
paper's element-granular skipping does MXU-block-granular skipping
capture?  Answer, quantified here:

  * UNSTRUCTURED ~50% CNN sparsity: capture ≈ 0 at any MXU-viable block —
    zeros are i.i.d.-ish, so a fully-zero 8×8+ block is ~0.5^64 rare.
    The paper's win at this granularity genuinely needs an ASIC.
  * STRUCTURED sparsity (dead channels / dead spatial regions — what
    trained ImageNet CNNs develop, cf. paper Fig. 7 TC/WC structure; and
    what token-level transformer sparsity looks like): capture climbs
    toward 1.0.  The sweep quantifies the transition.

Both findings feed EXPERIMENTS.md §Perf: the TPU port's value is (a) the
exactness-preserving mechanism + WDU schedule, (b) real wins on
structured sparsity, while the cost model (faithful ASIC, element-level)
reproduces the paper's own numbers.
"""
from __future__ import annotations

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import block_sparsity, capture_rate, element_sparsity
from repro.kernels import ops, ref, stats
from .common import capture_traces


def _audit_mask(x2: np.ndarray, block: int, rows: List[dict], **meta):
    m, n = x2.shape
    bb = block
    xp = jnp.asarray(np.pad(x2, ((0, -m % bb), (0, -n % bb))))
    rows.append({**meta, "block": bb,
                 "element_sparsity": round(float(element_sparsity(xp)), 4),
                 "block_sparsity": round(float(block_sparsity(xp, bb, bb)), 4),
                 "capture_rate": round(float(capture_rate(xp, bb, bb)), 4)})
    return rows[-1]["capture_rate"]


def kernel_audit() -> Tuple[List[dict], str]:
    rows: List[dict] = []
    unstructured = []
    # --- real traces, both GEMM layouts ---
    for net in ("vgg16", "googlenet"):
        acts, _ = capture_traces(net)
        for lname, a in list(acts.items())[:4]:
            px_c = a.reshape(-1, a.shape[-1]).astype(np.float32)
            c_px = px_c.T.copy()
            for b in (8, 16):
                unstructured.append(_audit_mask(
                    px_c, b, rows, net=net, layer=lname, layout="pix,chan"))
                _audit_mask(c_px, b, rows, net=net, layer=lname,
                            layout="chan,pix")

    # --- structure sweep: fraction of dead CHANNELS (WC sparsity) ---
    rng = np.random.default_rng(0)
    base = rng.standard_normal((256, 256)).astype(np.float32)
    struct_caps = {}
    for dead_frac in (0.0, 0.25, 0.5, 0.75):
        x = base.copy()
        n_dead = int(256 * dead_frac)
        x[:, :n_dead] = 0.0                       # dead channels
        x *= rng.random((256, 256)) > 0.3          # plus unstructured 30%
        cr = _audit_mask(x, 128, rows, net="synthetic",
                         layer=f"dead{dead_frac:.2f}", layout="pix,chan")
        struct_caps[dead_frac] = cr

    # --- exactness on a real mask ---
    a = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((48, 32)), jnp.float32)
    acts, _ = capture_traces("vgg16")
    first = next(iter(acts.values()))
    flat = (first.reshape(-1) != 0).astype(np.float32)
    relu_mask = jnp.asarray(np.resize(flat, (64, 32)))
    got = ops.relu_bwd_masked(a, w, relu_mask,
                              spec=ops.GemmSpec(block=(16, 16, 16)))
    want = ref.relu_bwd_masked(a, w, relu_mask, bm=16, bk=16, bn=16)
    exact = bool(np.allclose(got, want, rtol=1e-5, atol=1e-5))

    return rows, (
        f"unstructured_capture={np.mean(unstructured):.3f} "
        f"structured_capture(dead=0.5)={struct_caps[0.5]:.3f} "
        f"exact={exact}")


# ---------------------------------------------------------------------------
# Bitmap-op audit: sparsity metadata is COMPUTED once per tensor per step.
#
# The seed re-derived block bitmaps with dense scans up to 3× per activation
# per training step (fwd a_mask, bwd out_mask, bwd Xᵀ mask — all over the
# same ReLU footprint) and 2× per incoming gradient.  After the threading
# refactor the forward pass encodes each activation's fine bitmap exactly
# once (fused relu_encode) and the backward pass derives everything else,
# scanning dy at most once.  This audit counts the ops and verifies the
# backward results stayed exact against dense autodiff / the ref oracles.
# ---------------------------------------------------------------------------

def queue_cost_audit() -> Tuple[List[dict], str]:
    """Queue-construction cost: argsort reference vs Pallas prefix sum.

    The compact schedule's queue was built with a full argsort over the
    flattened (Mb·Nb) tile bitmap — O(T log T) comparisons on the step's
    critical path.  The prefix-sum builder does O(T) adds.  This audit
    records, per bitmap size: the modeled op counts, measured wall time of
    each construction (interpret-mode Pallas on CPU, so the *ratio* is
    indicative, the model is the claim), and bit-identity of the emitted
    queues against ``core.workredist.static_queue_order``.
    """
    import math
    import time

    from repro.core.workredist import static_queue_order

    rng = np.random.default_rng(0)
    rows: List[dict] = []
    all_match = True
    for mb, nb in ((8, 8), (16, 16), (32, 32), (64, 64), (128, 128)):
        t = mb * nb
        bm_np = (rng.random((mb, nb)) > 0.5).astype(np.int32)
        bm = jnp.asarray(bm_np)
        ri, rj, rn = static_queue_order(bm_np)

        def _timed(builder):
            stats.reset()
            out = ops.build_queue(bm, capacity=t, builder=builder)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(3):
                out = ops.build_queue(bm, capacity=t, builder=builder)
                jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / 3 * 1e6
            ii, jj, nl = (np.asarray(o) for o in out)
            match = bool(int(nl[0]) == rn and np.array_equal(ii, ri)
                         and np.array_equal(jj, rj))
            # the normalized stats reader: every construction above must be
            # attributed to THIS builder's queue:<builder> key, no other
            builds = stats.queue_builds(builder)
            assert builds == 4 and stats.queue_builds() == builds, \
                stats.counts()
            return us, match, builds

        us_sort, m_sort, n_sort = _timed("argsort")
        us_pfx, m_pfx, n_pfx = _timed("prefix_sum")
        all_match &= m_sort and m_pfx
        rows.append({
            "tiles": t, "shape": f"{mb}x{nb}",
            "argsort_ops": int(t * max(1, math.ceil(math.log2(t)))),
            "prefix_sum_ops": t,
            "op_ratio": round(max(1, math.ceil(math.log2(t))), 2),
            "us_argsort": round(us_sort, 1),
            "us_prefix_sum": round(us_pfx, 1),
            "counted_builds": n_sort + n_pfx,
            "match_reference": m_sort and m_pfx,
        })
    # A builder diverging from the reference order is a correctness bug,
    # not a data point — fail the audit (run.py exits nonzero for named
    # tables, which is the CI gate).
    assert all_match, "queue builders diverged from static_queue_order"
    big = rows[-1]
    return rows, (
        f"op_ratio@{big['shape']}={big['op_ratio']}x "
        f"queues_match_reference={all_match}")


def bitmap_op_audit() -> Tuple[List[dict], str]:
    from repro.core import policy as pol
    from repro.core.sparse_conv import depthwise_relu_conv, relu_conv
    from repro.core.sparse_linear import act_matmul

    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    rng = np.random.default_rng(0)
    rows: List[dict] = []

    def _count(label, sparse_fn, dense_fn, args):
        # jax.grad re-traces eagerly, so the recorded count == bitmap ops
        # in one training step's fwd+bwd graph for ONE activation.
        argnums = tuple(range(len(args)))
        stats.reset()
        gs = jax.grad(sparse_fn, argnums)(*args)
        n_act = stats.total("act")
        n_grad = stats.total("grad")
        # the dispatcher's normalized gemm:<schedule>:<g> launch keys — on
        # this policy every GEMM must dispatch compact, none dense/argsort
        n_gemm = stats.gemm_launches()
        n_compact = stats.gemm_launches(schedule="compact")
        assert n_gemm == n_compact and n_gemm > 0, stats.counts()
        gd = jax.grad(dense_fn, argnums)(*args)
        exact = all(
            np.allclose(a, b, rtol=3e-4, atol=3e-4) for a, b in zip(gs, gd))
        rows.append({"path": label, "bitmap_ops_act": n_act,
                     "bitmap_ops_grad": n_grad, "seed_ops_act": 3,
                     "gemm_launches": n_gemm, "exact_vs_dense": exact})
        return n_act, exact

    x = jnp.asarray(rng.standard_normal((40, 24)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((24, 32)), jnp.float32)
    n_mm, e_mm = _count(
        "act_matmul",
        lambda x, w: (act_matmul(x, w, policy, "relu") ** 2).sum(),
        lambda x, w: ((jnp.maximum(x, 0) @ w) ** 2).sum(),
        (x, w))

    xc = jnp.asarray(rng.standard_normal((2, 9, 11, 8)), jnp.float32)
    wc = jnp.asarray(rng.standard_normal((3, 3, 8, 8)), jnp.float32)

    def dense_conv(x, w):
        # dense reference oracle  # repro-lint: allow(CONV_FALLBACK)
        y = jax.lax.conv_general_dilated(
            jnp.maximum(x, 0), w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return (y ** 2).sum()

    n_cv, e_cv = _count(
        "relu_conv",
        lambda x, w: (relu_conv(x, w, 1, "SAME", policy) ** 2).sum(),
        dense_conv, (xc, wc))

    # grouped rows: the engine's batched per-group GEMMs keep the same
    # once-per-tensor metadata budget (one bitmap serves ALL groups).
    wg2 = jnp.asarray(rng.standard_normal((3, 3, 4, 8)), jnp.float32)

    def dense_grouped(x, w):
        # dense reference oracle  # repro-lint: allow(CONV_FALLBACK)
        y = jax.lax.conv_general_dilated(
            jnp.maximum(x, 0), w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=2)
        return (y ** 2).sum()

    n_g2, e_g2 = _count(
        "relu_conv_g2",
        lambda x, w: (relu_conv(x, w, 1, "SAME", policy,
                                groups=2) ** 2).sum(),
        dense_grouped, (xc, wg2))

    wdw = jnp.asarray(rng.standard_normal((3, 3, 1, 8)), jnp.float32)

    def dense_dw(x, w):
        # dense reference oracle  # repro-lint: allow(CONV_FALLBACK)
        y = jax.lax.conv_general_dilated(
            jnp.maximum(x, 0), w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=x.shape[-1])
        return (y ** 2).sum()

    n_dw, e_dw = _count(
        "depthwise_relu_conv",
        lambda x, w: (depthwise_relu_conv(x, w, 1, "SAME",
                                          policy) ** 2).sum(),
        dense_dw, (xc, wdw))

    # --- training-workload gate: the hot path is scan-free -------------
    # PR-8 contract: every dy bitmap is emitted by the producing GEMM's
    # bitmap_emit epilogue, so a FULL training step records ZERO standalone
    # bitmap scans (scan_pallas:* AND the xla_ref scan:* keys) — checked on
    # real network steps, not just the per-unit cells above.  Any nonzero
    # scan count fails the audit (run.py exits nonzero for named tables).
    from repro.data.pipeline import image_batch
    from repro.models.cnn import build_cnn
    from repro.models.ffn import FFNConfig, ffn_apply, ffn_init

    def _scan_free_step(label, loss_fn, params):
        stats.reset()
        grads = jax.grad(loss_fn)(params)
        finite = all(bool(np.all(np.isfinite(np.asarray(l))))
                     for l in jax.tree.leaves(grads))
        c = stats.counts()
        n_scan = sum(v for k, v in c.items()
                     if k.startswith("scan_pallas:") or k.startswith("scan:"))
        n_emit = c.get("emit:grad", 0)
        rows.append({"path": label, "bitmap_ops_act": stats.total("act"),
                     "bitmap_ops_grad": stats.total("grad"),
                     "seed_ops_act": "-", "gemm_launches":
                         stats.gemm_launches(), "exact_vs_dense": "-",
                     "scan_ops": n_scan, "emit_ops": n_emit,
                     "finite": finite})
        assert n_scan == 0, (label, c)
        assert n_emit >= 1, (label, c)
        assert finite, label
        return n_scan

    img, labels = image_batch(0, 0, batch=1, image_size=8, num_classes=10)
    scans = 0
    for net, width in (("vgg16", 0.0625), ("mobilenet", 0.0625)):
        model = build_cnn(net, image_size=8, width=width, num_classes=10)
        p0 = model.init(jax.random.key(0))
        scans += _scan_free_step(
            f"train:{net}",
            lambda q, _m=model: _m.loss(q, img, labels, policy), p0)

    cfg = FFNConfig(d_model=16, d_ff=32, activation="relu",
                    sparse_policy=policy)
    fp = ffn_init(jax.random.key(1), cfg)
    xin = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    yt = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    scans += _scan_free_step(
        "train:ffn_relu",
        lambda q: jnp.mean((ffn_apply(q, xin, cfg) - yt) ** 2), fp)

    return rows, (
        f"act_matmul_bitmaps_per_act={n_mm} relu_conv_bitmaps_per_act={n_cv} "
        f"depthwise_bitmaps_per_act={n_dw} (seed>=3) "
        f"exact={e_mm and e_cv and e_g2 and e_dw} "
        f"train_step_scan_ops={scans}")


# ---------------------------------------------------------------------------
# Launch-shape audit — the GemmSpec regression table.  The spec-driven
# redesign lowers EVERY GEMM (2-D included, as G=1) onto the grouped
# engine, which changes kernel launch shapes; this table pins the per-GEMM
# grid / block / queue-capacity geometry BEFORE (the legacy split
# orchestrators) vs AFTER (GemmSpec.launch_geometry) for a real model's
# workload, so future spec changes can't silently regress launch geometry.
# Uploaded as a CSV artifact by CI.
# ---------------------------------------------------------------------------

def _legacy_geometry(block, g, m, k, n, schedule, cap=None):
    """Pre-redesign launch geometry: masked_matmul's 2-D grid (Mb, Nb, Kb)
    and grouped_masked_matmul's (G, Mb, Nb, Kb); compact walked (cap, Kb)
    with cap defaulting to all tiles.  Kept here as the frozen reference."""
    bm, bk, bn = block
    ni, nk, nj = -(-m // bm), -(-k // bk), -(-n // bn)
    if schedule == "compact":
        cap = g * ni * nj if cap is None else cap
        return (cap, nk), cap
    grid = (ni, nj, nk) if g == 1 else (g, ni, nj, nk)
    return grid, 0


def _engine_grans(stage: str, cin: int, cout: int, groups: int,
                  block) -> Tuple[int, int, int]:
    """The per-axis bitmap granularities ``_conv_engine_fwd/_bwd`` resolve
    grouped specs with: gc = activation channel granularity, gcg = gradient
    channel granularity (both from ``conv_channel_granularity`` on the FULL
    channel counts).  Kept in the engine's exact stage order so the audit
    pins the geometry the engine actually launches, not a gran-1 proxy."""
    from repro.core.sparse_tensor import conv_channel_granularity

    gc = conv_channel_granularity(cin, block, groups)
    gcg = conv_channel_granularity(cout, block, groups)
    return {"fp": (1, gc, 1),
            "bp_dx": (1, gcg, gc),
            "wg": (gc, 1, gcg)}[stage]


def launch_shape_audit() -> Tuple[List[dict], str]:
    from repro.core import policy as pol
    from repro.models.cnn import build_cnn

    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    model = build_cnn("mobilenet", image_size=8, width=0.25, num_classes=10)
    workload = model.gemm_workload(batch=2)
    # plus the linear head GEMM (G=1, nominal tiles)
    workload.append({"layer": "head", "stage": "fp", "groups": 1,
                     "m": 2, "k": workload[-1]["n"], "n": 10})

    rows: List[dict] = []
    all_ok = True
    for w in workload:
        g, m, k, n = w["groups"], w["m"], w["k"], w["n"]
        # mirror the engine's resolution: nominal tiles at G=1 (the _mm
        # funnel), degenerate grouped_gemm_block tiles at the engine's true
        # channel granularities for grouped GEMMs
        base = policy.gemm_spec(groups=g) if g == 1 else \
            policy.gemm_spec(groups=g, dims=(m, k, n),
                             grans=_engine_grans(w["stage"], w["cin"],
                                                 w["cout"], g, policy.block))
        for schedule in ("predicated", "compact"):
            spec = base.with_(schedule=schedule)
            geom = spec.launch_geometry(m, k, n)
            legacy_grid, legacy_cap = _legacy_geometry(
                spec.block, g, m, k, n, schedule)
            if schedule == "compact":
                # the one queue + its capacity must be unchanged by the
                # collapse (same work stream, same overflow threshold)
                ok = geom["grid"] == legacy_grid \
                    and geom["queue_capacity"] == legacy_cap
            else:
                # G=1 grids gain exactly the leading unit group dim; true
                # grouped grids are unchanged
                want = (1, *legacy_grid) if g == 1 else legacy_grid
                ok = geom["grid"] == want
            all_ok &= ok
            rows.append({
                "layer": w["layer"], "stage": w["stage"], "schedule": schedule,
                "groups": g, "m": m, "k": k, "n": n,
                "block": "x".join(map(str, spec.block)),
                "grid_before": "x".join(map(str, legacy_grid)),
                "grid_after": "x".join(map(str, geom["grid"])),
                "queue_cap_before": legacy_cap,
                "queue_cap_after": geom["queue_capacity"],
                "geometry_ok": ok,
            })
    assert all_ok, "sparse_gemm launch geometry regressed vs the legacy contract"
    return rows, f"gemms={len(rows)} geometry_ok={all_ok}"


# ---------------------------------------------------------------------------
# Depthwise audit — the MobileNet acceptance gate: every dw layer routes
# through the sparse engine (zero dense-conv fallbacks), gradients bit-match
# dense autodiff across the stride/padding/groups sweep, and the metadata
# budget holds for a full dw/pw network step.  Wired into run.py's
# fail-on-error path and CI's mobilenet smoke cell.
# ---------------------------------------------------------------------------

def depthwise_audit() -> Tuple[List[dict], str]:
    from repro.core import policy as pol
    from repro.core.sparse_conv import relu_conv
    from repro.data.pipeline import image_batch
    from repro.models.cnn import build_cnn

    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    rng = np.random.default_rng(0)
    rows: List[dict] = []

    # --- grad-exactness sweep: stride × padding × groups ---
    all_exact = True
    c, m = 8, 8
    for groups in (2, c):
        for stride in (1, 2):
            for padding in ("SAME", "VALID"):
                x = jnp.asarray(rng.standard_normal((2, 9, 9, c)),
                                jnp.float32)
                w = jnp.asarray(
                    rng.standard_normal((3, 3, c // groups, m)), jnp.float32)

                def f(x, w):
                    return (relu_conv(x, w, stride, padding, policy,
                                      groups=groups) ** 2).sum()

                def g(x, w):
                    # dense reference oracle  # repro-lint: allow(CONV_FALLBACK)
                    y = jax.lax.conv_general_dilated(
                        jnp.maximum(x, 0), w, (stride, stride), padding,
                        dimension_numbers=("NHWC", "HWIO", "NHWC"),
                        feature_group_count=groups)
                    return (y ** 2).sum()

                gs = jax.grad(f, (0, 1))(x, w)
                gd = jax.grad(g, (0, 1))(x, w)
                exact = all(np.allclose(a, b, rtol=3e-4, atol=3e-4)
                            for a, b in zip(gs, gd))
                all_exact &= exact
                rows.append({"case": "grad_exactness", "groups": groups,
                             "stride": stride, "padding": padding,
                             "exact_vs_dense": exact, "finite": "-",
                             "dw_layers": "-", "dense_fallbacks": "-",
                             "act_bitmap_ops": "-", "grad_bitmap_ops": "-"})

    # --- MobileNet smoke: one fwd+bwd step, all 13 dw layers sparse ---
    model = build_cnn("mobilenet", image_size=8, width=0.0625, num_classes=10)
    params = model.init(jax.random.key(0))
    img, labels = image_batch(0, 0, batch=1, image_size=8, num_classes=10)
    stats.reset()
    grads = jax.grad(lambda p: model.loss(p, img, labels, policy))(params)
    finite = all(bool(np.all(np.isfinite(np.asarray(l))))
                 for l in jax.tree.leaves(grads))
    counts = stats.counts()
    fallbacks = counts.get("conv:dense_fallback", 0)
    n_dw = sum(1 for n in model.layers if getattr(n, "depthwise", False))
    rows.append({"case": "mobilenet_smoke", "groups": "per-layer C",
                 "stride": "-", "padding": "-", "exact_vs_dense": "-",
                 "finite": finite,
                 "dw_layers": n_dw, "dense_fallbacks": fallbacks,
                 "act_bitmap_ops": stats.total("act"),
                 "grad_bitmap_ops": stats.total("grad")})
    assert fallbacks == 0, counts
    assert finite, "MobileNet depthwise step produced non-finite gradients"
    assert all_exact, "grouped gradients diverged from dense autodiff"
    return rows, (
        f"dense_fallbacks={fallbacks} dw_layers={n_dw} "
        f"grouped_grads_exact={all_exact} finite={finite}")


def contract_audit() -> Tuple[List[dict], str]:
    """Static bitmap-contract verifier as a results table: one row per
    checker×workload with its violation count — the same rows
    ``python -m repro.analysis`` gates CI on (docs/static_analysis.md).
    All counts must be zero on main; any violation fails the table."""
    from repro.analysis import jaxpr_audit, lint
    from repro.analysis import kernel_sanitizer as ks

    rows: List[dict] = []
    all_violations = []

    for name in sorted(jaxpr_audit.WORKLOADS):
        vs = jaxpr_audit.audit_fn(jaxpr_audit.WORKLOADS[name](),
                                  workload=name)
        all_violations += vs
        rows.append({"checker": "jaxpr", "workload": name,
                     "violations": len(vs),
                     "codes": ";".join(sorted({v.code for v in vs})) or "-"})

    vs = ks.sanitize_all()
    all_violations += vs
    rows.append({"checker": "kernel", "workload": "sweep",
                 "violations": len(vs),
                 "codes": ";".join(sorted({v.code for v in vs})) or "-"})

    root = os.path.join(os.path.dirname(__file__), "..")
    roots = [p for r in ("src", "benchmarks", "examples")
             if os.path.isdir(p := os.path.join(root, r))]
    vs = lint.lint_paths(roots)
    all_violations += vs
    rows.append({"checker": "lint", "workload": "repo",
                 "violations": len(vs),
                 "codes": ";".join(sorted({v.code for v in vs})) or "-"})

    assert not all_violations, \
        [f"{v.checker}:{v.code}@{v.where}" for v in all_violations]
    return rows, f"checkers=3 rows={len(rows)} violations=0"


# ---------------------------------------------------------------------------
# Autotune audit: every measured-stats schedule selection is traceable.
#
# Runs the scripted eager session from benchmarks/wallclock.py against a
# fresh cache and renders the full decision log as the table — one row per
# resolve event (default / measured / retune / hit), each carrying the
# live-tile fractions and sample count it was decided from.  Asserts the
# session's expected arc: compact under sparse output tiles, a drift
# retune chain ending dense under all-live tiles, and two dims-keyed
# workloads holding DIFFERENT schedules simultaneously (the per-(spec,
# shape) selection contract).
# ---------------------------------------------------------------------------

def autotune_audit() -> Tuple[List[dict], str]:
    from benchmarks.wallclock import autotune_session

    selections, log, counters = autotune_session()
    by_phase = {}
    for s in selections:
        by_phase.setdefault(s["phase"], []).append(s["schedule"])

    assert by_phase["drift:sparse"][-1] == "compact", by_phase
    assert by_phase["drift:dense"][-1] == "dense", by_phase
    assert by_phase["shape:A"][-1] == "compact", by_phase
    assert by_phase["shape:B"][-1] == "dense", by_phase
    assert counters["retunes"] >= 1 and counters["hits"] >= 1, counters

    # traceability: every measured/retune decision cites >= min_samples
    # measured samples and a concrete live fraction ("default" rows are
    # explicitly the static fallback; "hit" rows replay a prior decision,
    # including its fractions); every log row carries the full field set.
    for r in log:
        assert set(r) == {"seq", "event", "key", "shape", "groups",
                          "schedule", "block", "live_frac", "operand_frac",
                          "samples"}, sorted(r)
        if r["event"] in ("measured", "retune"):
            assert r["live_frac"] is not None and r["samples"] >= 3, r

    schedules = sorted({r["schedule"] for r in log})
    return log, (
        f"events={len(log)} schedules={'/'.join(schedules)} "
        f"hits={counters['hits']} misses={counters['misses']} "
        f"retunes={counters['retunes']} traceable=True")
