"""Kernel audit: block-skip capture rate on real traces + structure sweep.

THE key hardware-adaptation question (DESIGN.md §2): how much of the
paper's element-granular skipping does MXU-block-granular skipping
capture?  Answer, quantified here:

  * UNSTRUCTURED ~50% CNN sparsity: capture ≈ 0 at any MXU-viable block —
    zeros are i.i.d.-ish, so a fully-zero 8×8+ block is ~0.5^64 rare.
    The paper's win at this granularity genuinely needs an ASIC.
  * STRUCTURED sparsity (dead channels / dead spatial regions — what
    trained ImageNet CNNs develop, cf. paper Fig. 7 TC/WC structure; and
    what token-level transformer sparsity looks like): capture climbs
    toward 1.0.  The sweep quantifies the transition.

Both findings feed EXPERIMENTS.md §Perf: the TPU port's value is (a) the
exactness-preserving mechanism + WDU schedule, (b) real wins on
structured sparsity, while the cost model (faithful ASIC, element-level)
reproduces the paper's own numbers.
"""
from __future__ import annotations

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import block_sparsity, capture_rate, element_sparsity
from repro.kernels import ops, ref
from .common import capture_traces


def _audit_mask(x2: np.ndarray, block: int, rows: List[dict], **meta):
    m, n = x2.shape
    bb = block
    xp = jnp.asarray(np.pad(x2, ((0, -m % bb), (0, -n % bb))))
    rows.append({**meta, "block": bb,
                 "element_sparsity": round(float(element_sparsity(xp)), 4),
                 "block_sparsity": round(float(block_sparsity(xp, bb, bb)), 4),
                 "capture_rate": round(float(capture_rate(xp, bb, bb)), 4)})
    return rows[-1]["capture_rate"]


def kernel_audit() -> Tuple[List[dict], str]:
    rows: List[dict] = []
    unstructured = []
    # --- real traces, both GEMM layouts ---
    for net in ("vgg16", "googlenet"):
        acts, _ = capture_traces(net)
        for lname, a in list(acts.items())[:4]:
            px_c = a.reshape(-1, a.shape[-1]).astype(np.float32)
            c_px = px_c.T.copy()
            for b in (8, 16):
                unstructured.append(_audit_mask(
                    px_c, b, rows, net=net, layer=lname, layout="pix,chan"))
                _audit_mask(c_px, b, rows, net=net, layer=lname,
                            layout="chan,pix")

    # --- structure sweep: fraction of dead CHANNELS (WC sparsity) ---
    rng = np.random.default_rng(0)
    base = rng.standard_normal((256, 256)).astype(np.float32)
    struct_caps = {}
    for dead_frac in (0.0, 0.25, 0.5, 0.75):
        x = base.copy()
        n_dead = int(256 * dead_frac)
        x[:, :n_dead] = 0.0                       # dead channels
        x *= rng.random((256, 256)) > 0.3          # plus unstructured 30%
        cr = _audit_mask(x, 128, rows, net="synthetic",
                         layer=f"dead{dead_frac:.2f}", layout="pix,chan")
        struct_caps[dead_frac] = cr

    # --- exactness on a real mask ---
    a = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((48, 32)), jnp.float32)
    acts, _ = capture_traces("vgg16")
    first = next(iter(acts.values()))
    flat = (first.reshape(-1) != 0).astype(np.float32)
    relu_mask = jnp.asarray(np.resize(flat, (64, 32)))
    got = ops.relu_bwd_masked(a, w, relu_mask, block=(16, 16, 16))
    want = ref.relu_bwd_masked(a, w, relu_mask, bm=16, bk=16, bn=16)
    exact = bool(np.allclose(got, want, rtol=1e-5, atol=1e-5))

    return rows, (
        f"unstructured_capture={np.mean(unstructured):.3f} "
        f"structured_capture(dead=0.5)={struct_caps[0.5]:.3f} "
        f"exact={exact}")
