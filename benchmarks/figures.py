"""One function per paper figure/table (see DESIGN.md §5 index).

Each ``fig*/table*`` function returns (rows, derived) where rows is a list
of CSV-able dicts and derived is a one-line summary metric used by run.py's
``name,us_total,derived`` output (whole-table wall time; per-call fenced
medians live in benchmarks/wallclock.py).
"""
from __future__ import annotations

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from typing import Dict, List, Tuple

import numpy as np

from repro.core import costmodel as cm
from repro.core import workredist as wr
from .common import (build_cost_inputs, capture_traces, layer_speedups,
                     network_totals)

NETS = ("vgg16", "googlenet", "resnet18", "densenet121", "mobilenet")


# ---------------------------------------------------------------------------
# Fig. 3d — min/avg/max sparsity per network across a batch
# ---------------------------------------------------------------------------

def fig03_sparsity() -> Tuple[List[dict], str]:
    rows = []
    for net in NETS:
        acts, _ = capture_traces(net)
        per_sample = []
        for a in acts.values():
            sp = (a == 0).mean(axis=tuple(range(1, a.ndim)))   # per sample
            per_sample.append(sp)
        sp = np.stack(per_sample)                              # (layers, B)
        rows.append({
            "network": net,
            "min_sparsity": round(float(sp.mean(axis=0).min()), 4),
            "avg_sparsity": round(float(sp.mean()), 4),
            "max_sparsity": round(float(sp.mean(axis=0).max()), 4),
        })
    avg = np.mean([r["avg_sparsity"] for r in rows])
    return rows, f"avg_sparsity={avg:.3f} (paper reports 0.30-0.70)"


# ---------------------------------------------------------------------------
# Fig. 11a — VGG16 layer-wise BP speedups
# ---------------------------------------------------------------------------

def fig11_vgg() -> Tuple[List[dict], str]:
    sp = layer_speedups("vgg16", phase="bp")
    rows = [{"layer": l,
             "IN": round(sp["IN"][i], 3),
             "IN_OUT": round(sp["IN_OUT"][i], 3),
             "IN_OUT_WR": round(sp["IN_OUT_WR"][i], 3)}
            for i, l in enumerate(sp["layer"])]
    mx = max(sp["IN_OUT_WR"])
    mn = min(sp["IN_OUT_WR"])
    return rows, f"layer_speedup={mn:.2f}x..{mx:.2f}x (paper: 1.46x..7.61x)"


# ---------------------------------------------------------------------------
# Fig. 11b — GoogLeNet Inception-3b
# ---------------------------------------------------------------------------

def fig11_googlenet() -> Tuple[List[dict], str]:
    sp = layer_speedups("googlenet", phase="bp")
    rows = [{"layer": l,
             "IN": round(sp["IN"][i], 3),
             "IN_OUT": round(sp["IN_OUT"][i], 3),
             "IN_OUT_WR": round(sp["IN_OUT_WR"][i], 3)}
            for i, l in enumerate(sp["layer"])]
    return rows, (f"block_speedup={min(sp['IN_OUT_WR']):.2f}x.."
                  f"{max(sp['IN_OUT_WR']):.2f}x (paper: 2.6x..12.6x)")


# ---------------------------------------------------------------------------
# Fig. 12a/b — DenseNet block1 / MobileNet pointwise convs
# ---------------------------------------------------------------------------

def fig12_densenet() -> Tuple[List[dict], str]:
    sp = layer_speedups("densenet121", phase="bp")
    rows = [{"layer": l, "IN_OUT_WR": round(sp["IN_OUT_WR"][i], 3)}
            for i, l in enumerate(sp["layer"])]
    return rows, (f"speedup={min(sp['IN_OUT_WR']):.2f}x.."
                  f"{max(sp['IN_OUT_WR']):.2f}x (paper: 1.69x..3.32x)")


def fig12_mobilenet() -> Tuple[List[dict], str]:
    """MobileNet layer speedups — traces now captured through the sparse
    depthwise lowering, and the dw layers are modeled as grouped convs
    (ConvSpec.groups == C) rather than approximated as full convs, so they
    get their own rows next to the paper's pw bars."""
    sp = layer_speedups("mobilenet", phase="bp")
    rows = [{"layer": l, "kind": "dw" if l.startswith("dw") else "pw",
             "IN_OUT_WR": round(sp["IN_OUT_WR"][i], 3)}
            for i, l in enumerate(sp["layer"])
            if l.startswith(("pw", "dw"))]
    pw = [r["IN_OUT_WR"] for r in rows if r["kind"] == "pw"]
    dw = [r["IN_OUT_WR"] for r in rows if r["kind"] == "dw"]
    return rows, (f"pw_speedup={min(pw):.2f}x..{max(pw):.2f}x "
                  f"(paper: 1.25x..2.1x) "
                  f"dw_speedup={min(dw):.2f}x..{max(dw):.2f}x")


# ---------------------------------------------------------------------------
# Fig. 13 — ResNet18 block2 (BN ⇒ OUT-only in BP)
# ---------------------------------------------------------------------------

def fig13_resnet() -> Tuple[List[dict], str]:
    specs, traces = build_cost_inputs("resnet18")
    rows = []
    gains = []
    for spec, trace in zip(specs, traces):
        dc = cm.layer_cost(spec, trace, "DC").bp.cycles
        inp = cm.layer_cost(spec, trace, "IN").bp.cycles
        full = cm.layer_cost(spec, trace, "IN_OUT_WR").bp.cycles
        rows.append({"layer": spec.name, "has_bn": spec.has_bn,
                     "IN_gain": round(dc / inp, 3),
                     "IN_OUT_WR_gain": round(dc / full, 3)})
        gains.append(dc / full)
    mean_imp = float(np.mean([g - 1 for g in gains]))
    return rows, (f"mean_block_improvement={mean_imp:.2f} "
                  f"(paper: ~0.45 mean, 0.16-0.73 range)")


# ---------------------------------------------------------------------------
# Fig. 15 — end-to-end normalized execution with FP/BP/WG breakdown
# ---------------------------------------------------------------------------

def fig15_end2end() -> Tuple[List[dict], str]:
    rows = []
    overall = {}
    for net in NETS:
        totals = network_totals(net)
        dc = totals["DC"]["total_cycles"]
        for sc in ("DC", "IN", "IN_OUT", "IN_OUT_WR"):
            t = totals[sc]
            rows.append({
                "network": net, "scenario": sc,
                "normalized_total": round(t["total_cycles"] / dc, 4),
                "fp_frac": round(t["fp_cycles"] / dc, 4),
                "bp_frac": round(t["bp_cycles"] / dc, 4),
                "wg_frac": round(t["wg_cycles"] / dc, 4),
            })
        overall[net] = dc / totals["IN_OUT_WR"]["total_cycles"]
    s = " ".join(f"{k}={v:.2f}x" for k, v in overall.items())
    return rows, s + " (paper: vgg~2x goog~2.18x mobile~2.13x dense~1.7x res~1.66x)"


# ---------------------------------------------------------------------------
# Fig. 16 — impact of lane reconfiguration
# ---------------------------------------------------------------------------

def fig16_reconfig() -> Tuple[List[dict], str]:
    rows = []
    for crs, label in ((64, "1x1x64"), (576, "3x3x64")):
        for mode in ("none", "direct", "hierarchical"):
            rows.append({"receptive_field": label, "mode": mode,
                         "lane_utilization":
                             round(cm.lane_utilization(crs, cm.DEFAULT_HW,
                                                       mode), 4)})
    r9 = [r for r in rows if r["receptive_field"] == "3x3x64"]
    gain = r9[2]["lane_utilization"] / r9[0]["lane_utilization"]
    return rows, f"hierarchical_gain_3x3x64={gain:.2f}x (paper: ~1.75x)"


# ---------------------------------------------------------------------------
# Fig. 17 — tile latency min/avg/max under WR (GoogLeNet)
# ---------------------------------------------------------------------------

def fig17_tiles() -> Tuple[List[dict], str]:
    specs, traces = build_cost_inputs("googlenet")
    rows = []
    utils = {}
    for redis, label in ((False, "no_WR"), (True, "WR")):
        # aggregate over conv layers with spatial maps
        us = []
        for spec, trace in zip(specs, traces):
            if trace.bp_active_map is None:
                continue
            work = wr.tile_work_from_mask(trace.bp_active_map, 16, 16,
                                          spec.m * spec.r * spec.s)
            r = wr.simulate(work, redistribute=redis)
            rows.append({"layer": spec.name, "mode": label,
                         "min": round(r.busy_min, 1),
                         "avg": round(r.busy_avg, 1),
                         "max": round(r.busy_max, 1),
                         "makespan": round(r.makespan, 1),
                         "utilization": round(r.utilization, 4)})
            us.append(r.utilization)
        utils[label] = float(np.mean(us)) if us else 1.0
    return rows, (f"utilization no_WR={utils['no_WR']:.3f} → "
                  f"WR={utils['WR']:.3f} (paper: ~0.70 → ~0.829)")


# ---------------------------------------------------------------------------
# Table 2 — platform comparison (iteration latency, batch 16)
# ---------------------------------------------------------------------------

# Published numbers from the paper's Table 2 (cited constants).
_TABLE2_PUBLISHED = [
    # platform, mode, vgg16_ms, res18_ms, power_w, peak_gops
    ("Dual Xeon E5 2560 v3", "CPU, Dense", 8495.0, 2195.0, 85, 614.4),
    ("NVidia GTX 1080 Ti", "GPU, Dense", 128.0, 32.78, 225, 11000),
    ("DaDianNao", "Acc, Dense", 526.0, 61.1, 16.3, 4964),
    ("CNVLUTIN", "Acc, Input Sparse", 365.0, 48.3, 17.4, 4964),
    ("LNPU", "Acc, Input Sparse", 4742.0, 684.0, 0.367, 638),
    ("SparTANN", "Acc, In Sparse(BP&WG)", 12831.0, 1789.0, 0.59, 380),
    ("Selective Grad", "Acc, In Sparse(BP)", 480.0, 61.1, 16.3, 4964),
    ("This Work (paper)", "Acc, In+Out Sparse", 166.81, 23.26, 19.2, 5466),
]


def table2_platforms() -> Tuple[List[dict], str]:
    rows = [{"platform": p, "mode": m, "vgg16_ms": v, "res18_ms": r,
             "power_w": w, "peak_gops": g, "source": "paper Table 2"}
            for p, m, v, r, w, g in _TABLE2_PUBLISHED]
    ours = {}
    for net in ("vgg16", "resnet18"):
        t = network_totals(net)["IN_OUT_WR"]
        ours[net] = t["iteration_ms"]
    rows.append({"platform": "This Work (repro cost model)",
                 "mode": "Acc, In+Out Sparse",
                 "vgg16_ms": round(ours["vgg16"], 2),
                 "res18_ms": round(ours["resnet18"], 2),
                 "power_w": 19.2, "peak_gops": 5466,
                 "source": "trace-driven cost model, this repo"})
    return rows, (f"repro vgg16={ours['vgg16']:.1f}ms res18="
                  f"{ours['resnet18']:.1f}ms (paper: 166.81 / 23.26)")


ALL_FIGURES = {
    "fig03_sparsity": fig03_sparsity,
    "fig11_vgg": fig11_vgg,
    "fig11_googlenet": fig11_googlenet,
    "fig12_densenet": fig12_densenet,
    "fig12_mobilenet": fig12_mobilenet,
    "fig13_resnet": fig13_resnet,
    "fig15_end2end": fig15_end2end,
    "fig16_reconfig": fig16_reconfig,
    "fig17_tiles": fig17_tiles,
    "table2_platforms": table2_platforms,
}
