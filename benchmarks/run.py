# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import csv
import io
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def main() -> None:
    from benchmarks.figures import ALL_FIGURES
    from benchmarks.kernel_audit import bitmap_op_audit, kernel_audit
    from benchmarks.roofline import roofline_rows

    benches = dict(ALL_FIGURES)
    benches["kernel_audit"] = kernel_audit
    benches["bitmap_op_audit"] = bitmap_op_audit
    benches["roofline_table"] = roofline_rows

    os.makedirs(RESULTS_DIR, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows, derived = fn()
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{e!r}")
            continue
        us = (time.time() - t0) * 1e6
        # persist full rows per table
        if rows:
            path = os.path.join(RESULTS_DIR, f"{name}.csv")
            with open(path, "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
                w.writeheader()
                w.writerows(rows)
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
