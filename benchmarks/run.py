# One function per paper table. Print ``name,us_total,derived`` CSV.
# ``us_total`` is the whole-table wall time (trace + compile + every row's
# calls) — it was previously mislabeled ``us_per_call``, which it never was.
# Per-call medians with compile excluded live in benchmarks/wallclock.py.
# Usage: python benchmarks/run.py [table ...] — no args runs every table;
# naming tables (e.g. ``queue_cost_audit``) runs just those (CI artifacts).
import csv
import io
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

HEADER = "name,us_total,derived"


def write_rows(path: str, rows) -> None:
    """Persist one table's rows as CSV.

    Tables may emit heterogeneous rows (e.g. a summary row with extra keys);
    ``fieldnames=rows[0].keys()`` used to crash with ``ValueError: dict
    contains fields not in fieldnames`` on the first such table.  Use the
    union of all keys in first-seen order and blank-fill the gaps.
    """
    fieldnames = []
    for r in rows:
        for k in r.keys():
            if k not in fieldnames:
                fieldnames.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fieldnames, restval="")
        w.writeheader()
        w.writerows(rows)


def main() -> None:
    from benchmarks.figures import ALL_FIGURES
    from benchmarks.kernel_audit import (
        autotune_audit, bitmap_op_audit, contract_audit, depthwise_audit,
        kernel_audit, launch_shape_audit, queue_cost_audit)
    from benchmarks.roofline import roofline_rows

    benches = dict(ALL_FIGURES)
    benches["kernel_audit"] = kernel_audit
    benches["bitmap_op_audit"] = bitmap_op_audit
    benches["queue_cost_audit"] = queue_cost_audit
    benches["launch_shape_audit"] = launch_shape_audit
    benches["depthwise_audit"] = depthwise_audit
    benches["contract_audit"] = contract_audit
    benches["autotune_audit"] = autotune_audit
    benches["roofline_table"] = roofline_rows

    only = sys.argv[1:]
    if only:
        unknown = [n for n in only if n not in benches]
        assert not unknown, f"unknown tables {unknown}; have {sorted(benches)}"
        benches = {n: benches[n] for n in only}

    os.makedirs(RESULTS_DIR, exist_ok=True)
    failed = []
    print(HEADER)
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows, derived = fn()
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{e!r}")
            failed.append(name)
            continue
        us = (time.time() - t0) * 1e6
        # persist full rows per table
        if rows:
            write_rows(os.path.join(RESULTS_DIR, f"{name}.csv"), rows)
        print(f"{name},{us:.0f},{derived}")
    # Explicitly-named tables are CI gates: an error must fail the job
    # (the full sweep stays best-effort so one bad table can't hide the
    # others' rows).
    if only and failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
