"""§Roofline renderer: reads the dry-run JSONL and emits the per-cell
roofline table (markdown + CSV rows) used by EXPERIMENTS.md."""
from __future__ import annotations

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import json
from typing import List, Optional, Tuple

_DIR = os.path.join(os.path.dirname(__file__), "results")
_FINAL = os.path.join(_DIR, "dryrun_final.jsonl")
RESULTS = _FINAL if os.path.exists(_FINAL) else os.path.join(_DIR, "dryrun.jsonl")


def load(path: str = RESULTS, mesh: Optional[str] = "16x16") -> List[dict]:
    recs = {}
    if not os.path.exists(path):
        return []
    for line in open(path):
        r = json.loads(line)
        if r.get("skipped") or not r.get("ok"):
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r   # latest wins
    return list(recs.values())


def roofline_rows(path: str = RESULTS, mesh: str = "16x16") -> Tuple[List[dict], str]:
    rows = []
    worst = (None, 1.0)
    for r in load(path, mesh):
        rl = r["roofline"]
        mem = r.get("memory", {})
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": round(rl["compute_s"], 6),
            "memory_s": round(rl["memory_s"], 6),
            "collective_s": round(rl["collective_s"], 6),
            "dominant": rl["dominant"],
            "useful_flop_fraction":
                rl["useful_flop_fraction"] and round(rl["useful_flop_fraction"], 3),
            "roofline_fraction":
                rl["roofline_fraction"] and round(rl["roofline_fraction"], 4),
            "live_gib": round(mem.get("live_bytes", 0) / 2 ** 30, 2),
            "fits_16g": mem.get("fits_16g"),
        })
        rf = rl.get("roofline_fraction")
        if r["shape"] == "train_4k" and rf and rf < worst[1]:
            worst = (f"{r['arch']}×{r['shape']}", rf)
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows, f"cells={len(rows)} worst_train_rf={worst[0]}@{worst[1]}"


def markdown_table(path: str = RESULTS, mesh: str = "16x16") -> str:
    rows, _ = roofline_rows(path, mesh)
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful-FLOP frac | roofline frac | live GiB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flop_fraction']} | "
            f"{r['roofline_fraction']} | {r['live_gib']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
