"""Shared benchmark machinery: trace capture → cost-model inputs.

Methodology (mirrors paper §5): train each CNN briefly on the synthetic
zero-mean image stream (CPU-feasible reduced geometry), capture per-layer
post-ReLU activations, derive the cost-model densities:

  x_density        = measured nonzero fraction of the layer's input act
  out_mask_density = same tensor's mask density (σ' footprint — identical
                     by the paper's §3.2 theorem, property-tested)
  g_in_density     = measured output-act density if the output feeds a
                     ReLU with NO BatchNorm in between, else 1.0 (BN
                     re-densifies gradients — Fig. 3c rule)

The cost model is then evaluated at the paper's full ImageNet geometry
(224², width 1.0, batch 16) with these densities; spatial work maps are
resampled from the captured masks.
"""
from __future__ import annotations

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core.sparsity import element_sparsity
from repro.data.pipeline import image_batch
from repro.models.cnn import build_cnn

BATCH = 16


@functools.lru_cache(maxsize=None)
def capture_traces(name: str, *, train_steps: int = 3, image_size: int = 32,
                   width: float = 0.25, batch: int = 8
                   ) -> Tuple[Dict[str, np.ndarray], Dict[str, float]]:
    """Returns (captured acts, per-layer density) after a few real steps."""
    model = build_cnn(name, image_size=image_size, width=width,
                      num_classes=100)
    params = model.init(jax.random.key(0))
    for step in range(train_steps):
        img, labels = image_batch(0, step, batch=batch,
                                  image_size=image_size, num_classes=100)
        grads = jax.grad(lambda p: model.loss(p, img, labels))(params)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    cap: Dict[str, jnp.ndarray] = {}
    img, _ = image_batch(0, train_steps, batch=batch, image_size=image_size,
                         num_classes=100)
    model.apply(params, img, capture=cap)
    acts = {k: np.asarray(v) for k, v in cap.items()}
    dens = {k: 1.0 - float(element_sparsity(v)) for k, v in cap.items()}
    return acts, dens


def _resample_map(m: np.ndarray, target: int) -> np.ndarray:
    """Work-map resample.  Downsampling uses nearest-neighbour; when the
    full geometry is LARGER than the captured one we keep the captured
    resolution — upsampling would tile constant blocks into the 16×16 PE
    grid and fabricate spatial imbalance the real 224² maps don't have
    (each full-geometry PE tile averages ≥7² locations)."""
    h, w = m.shape
    if target >= h:
        return m
    yi = (np.arange(target) * h // target).clip(0, h - 1)
    xi = (np.arange(target) * w // target).clip(0, w - 1)
    return m[np.ix_(yi, xi)]


def build_cost_inputs(name: str, *, batch: int = BATCH
                      ) -> Tuple[List[cm.ConvSpec], List[cm.LayerTrace]]:
    """Full-geometry ConvSpecs + traces with measured densities."""
    acts, dens = capture_traces(name)
    full = build_cnn(name, image_size=224, width=1.0, num_classes=1000)
    specs = full.conv_specs(batch=batch)

    # walk specs in order; the producer of spec i's input is spec i-1 (for
    # sequential nets) — x_density keyed by the previous captured layer.
    traces: List[cm.LayerTrace] = []
    prev_name = None
    for s in specs:
        x_d = dens.get(prev_name, 1.0) if s.input_is_relu else 1.0
        own_d = dens.get(s.name, 0.5)
        g_in = own_d if (s.output_feeds_relu and not s.has_bn) else 1.0
        # spatial BP work map from the input activation mask
        bp_map = None
        if prev_name in acts and s.input_is_relu:
            a = acts[prev_name]
            nz = (a[0] != 0).sum(axis=-1).astype(np.float64)  # (H, W)
            bp_map = _resample_map(nz, s.h)
        fp_map = None
        if prev_name in acts:
            a = acts[prev_name]
            nz = (a[0] != 0).sum(axis=-1).astype(np.float64)
            fp_map = _resample_map(nz, s.u)
        traces.append(cm.LayerTrace(
            x_density=x_d, g_in_density=g_in, out_mask_density=x_d,
            fp_active_map=fp_map, bp_active_map=bp_map))
        prev_name = s.name
    return specs, traces


def layer_speedups(name: str, scenarios=("DC", "IN", "IN_OUT", "IN_OUT_WR"),
                   phase: str = "bp") -> Dict[str, List[float]]:
    """Per-layer speedup of each scenario over DC for the given phase."""
    specs, traces = build_cost_inputs(name)
    out: Dict[str, List[float]] = {s: [] for s in scenarios}
    out["layer"] = [s.name for s in specs]
    for spec, trace in zip(specs, traces):
        base = getattr(cm.layer_cost(spec, trace, "DC"), phase).cycles
        for sc in scenarios:
            c = getattr(cm.layer_cost(spec, trace, sc), phase).cycles
            out[sc].append(base / c if c > 0 else 1.0)
    return out


def network_totals(name: str) -> Dict[str, Dict[str, float]]:
    specs, traces = build_cost_inputs(name)
    return {sc: cm.network_cost(specs, traces, sc)
            for sc in ("DC", "IN", "IN_OUT", "IN_OUT_WR")}
