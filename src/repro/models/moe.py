"""Mixture-of-Experts with capacity-based scatter dispatch (EP-shardable).

Top-k softmax routing with per-expert capacity (Switch/GShard style): token
positions are assigned by a cumulative-sum over the one-hot routing matrix;
overflow tokens are dropped (their combine weight is zero, residual carries
them).  Expert compute is a batched einsum over the stacked expert weights,
so the expert axis shards cleanly on the mesh "model" axis (EP) and the
compiled FLOPs reflect *active* compute (tokens × top_k × expert FFN), not
n_experts × dense — which keeps the roofline analysis honest.

Supports shared experts (DeepSeek-V2) and a load-balancing auxiliary loss.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import activation_fn, dense_init
from .ffn import FFNConfig, ffn_apply, ffn_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    activation: str = "silu_glu"
    router_aux_weight: float = 0.01
    # device-limited routing (DeepSeek-V2 §2.1.3): restrict each token's
    # top-k to experts living on at most ``top_groups`` of
    # ``device_groups`` expert shards — bounds EP all-to-all fan-out.
    device_groups: int = 0          # 0 → unrestricted
    top_groups: int = 0


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    e, f = cfg.n_experts, cfg.d_ff_expert
    std = d_model ** -0.5
    def ew(k, a, b):
        return (jax.random.normal(k, (e, a, b), jnp.float32) * std).astype(dtype)
    p: Params = {"router": dense_init(ks[0], d_model, e, jnp.float32)}
    if cfg.activation.endswith("_glu"):
        p.update(w_gate=ew(ks[1], d_model, f), w_up=ew(ks[2], d_model, f),
                 w_down=ew(ks[3], f, d_model))
    else:
        p.update(w_up=ew(ks[1], d_model, f), w_down=ew(ks[2], f, d_model))
    if cfg.n_shared_experts > 0:
        shared_cfg = FFNConfig(d_model, cfg.d_ff_shared or cfg.d_ff_expert
                               * cfg.n_shared_experts, cfg.activation)
        p["shared"] = ffn_init(ks[4], shared_cfg, dtype)
    return p


def _expert_ffn(p: Params, xs: jnp.ndarray, cfg: MoEConfig) -> jnp.ndarray:
    """xs: (E, C, d) -> (E, C, d), batched over the (shardable) expert axis."""
    if cfg.activation.endswith("_glu"):
        act = activation_fn(cfg.activation.split("_")[0])
        h = act(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
    else:
        act = activation_fn(cfg.activation)
        h = act(jnp.einsum("ecd,edf->ecf", xs, p["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_apply(
    params: Params,
    x: jnp.ndarray,                      # (..., d_model)
    cfg: MoEConfig,
    *,
    capacity: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    if capacity is None:
        capacity = max(1, int(t * k / e * cfg.capacity_factor))

    logits = (xt.astype(jnp.float32) @ params["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    if cfg.device_groups and cfg.top_groups:
        g = cfg.device_groups
        pg = probs.reshape(t, g, e // g)
        gscore = pg.max(axis=-1)                                # (T, G)
        _, gidx = jax.lax.top_k(gscore, cfg.top_groups)
        gmask = jax.nn.one_hot(gidx, g).sum(axis=1)             # (T, G)
        probs = (pg * gmask[..., None]).reshape(t, e)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch):  E * Σ_e f_e · P_e
    me = probs.mean(axis=0)
    one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], e)
    ce = one_hot_top1.mean(axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    # Capacity assignment: position of each (token, choice) within its
    # expert's buffer, in token order (GShard).
    oh = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)          # (T, k, E)
    ohf = oh.reshape(t * k, e)
    pos = jnp.cumsum(ohf, axis=0) - ohf                        # (T*k, E)
    pos = (pos * ohf).sum(-1).reshape(t, k)                    # (T, k)
    keep = pos < capacity
    slot = gate_idx * capacity + jnp.minimum(pos, capacity - 1)  # (T, k)
    slot = jnp.where(keep, slot, e * capacity)                 # overflow row

    # Scatter tokens into expert buffers: (E*C+1, d), sentinel last row.
    # NOTE on sharding: we deliberately leave the buffer's placement to
    # the partitioner.  Both pinning attempts were measured and REFUTED
    # (EXPERIMENTS.md §Perf): rows→dp 181.7→247.0 GiB, experts→model
    # 68→107 GiB on grok.  The remaining u32 select-mask cost of the
    # dispatch scatter is a known XLA SPMD limitation; the production fix
    # is a shard_map ragged all-to-all dispatch (future work).
    from repro.sharding import constraint
    buf = jnp.zeros((e * capacity + 1, d), xt.dtype)
    token_rep = jnp.repeat(jnp.arange(t), k)
    buf = buf.at[slot.reshape(-1)].set(xt[token_rep], mode="drop")
    # EP: pin the expert buffers to the model axis so the expert batched
    # matmuls run sharded (otherwise the compiler may replicate E·C·d —
    # measured tens of GiB on the 32k-prefill MoE cells).
    xs = constraint(buf[:-1].reshape(e, capacity, d), "moe_ecd")
    ys = constraint(_expert_ffn(params, xs, cfg), "moe_ecd")
    ys = ys.reshape(e * capacity, d)
    ys = jnp.concatenate([ys, jnp.zeros((1, d), ys.dtype)], axis=0)

    # Combine: gather each token's k outputs, weight by normalized gates.
    # Keep the (T, k, d) gather in the activation dtype — an f32 combine
    # doubles the live footprint for no accuracy benefit (weights are f32).
    gathered = ys[slot]                                        # (T, k, d)
    w = (gate_vals * keep.astype(gate_vals.dtype))[..., None]
    y = (gathered * w.astype(gathered.dtype)).sum(axis=1).astype(x.dtype)

    if "shared" in params:
        shared_cfg = FFNConfig(d, cfg.d_ff_shared or cfg.d_ff_expert
                               * cfg.n_shared_experts, cfg.activation)
        y = y + ffn_apply(params["shared"], xt, shared_cfg)
    return y.reshape(shape), aux
