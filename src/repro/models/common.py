"""Shared building blocks: norms, rotary embeddings, initializers.

The model zoo is pure-functional JAX: a "module" is an ``init(key, cfg) ->
params`` / ``apply(params, x, ...) -> y`` pair over plain pytrees, so
everything composes with pjit sharding annotations and lax.scan layer
stacking without a framework dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    assert head_dim % 2 == 0, head_dim
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, Dh); positions: broadcastable to (..., T)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., T, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation_fn(name: str):
    return {
        "relu": lambda x: jnp.maximum(x, 0),
        "relu2": lambda x: jnp.square(jnp.maximum(x, 0)),
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
    }[name]
