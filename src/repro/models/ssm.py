"""Mamba-style selective SSM block (for jamba hybrid layers).

Training path: chunked selective scan — sequential ``lax.scan`` over chunks
carrying the (B, d_inner, d_state) hidden state, with an intra-chunk
associative scan; the chunk body is rematerialized so the live footprint is
O(B·chunk·d_inner·d_state / model-shards) instead of O(T·…).

Decode path: single-step recurrence over (conv window, ssm state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0         # 0 → ceil(d_model / 16)
    chunk: int = 256
    unroll: int = 1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def ssm_init(key, cfg: SSMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    di, ds, r = cfg.d_inner, cfg.d_state, cfg.rank
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "w_in": dense_init(ks[0], cfg.d_model, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                   * (cfg.d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_x": dense_init(ks[2], di, r + 2 * ds, dtype),
        "w_dt": dense_init(ks[3], r, di, dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(a),                       # (di, ds) f32
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], di, cfg.d_model, dtype),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prefix: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. u: (B,T,di); prefix: (B,d_conv-1,di)."""
    dc = w.shape[0]
    up = jnp.concatenate([prefix, u], axis=1)
    out = sum(up[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(dc))
    return out + b[None, None, :]


def _selective_scan_chunked(dt, b_in, c_in, u, a, cfg: SSMConfig):
    """y_t = C_tᵀ h_t with h_t = exp(dt_t·a) ⊙ h_{t-1} + dt_t·B_t·u_t.

    The (B, ·, di, ds) discretized tensors are built PER CHUNK inside the
    rematerialized body — materializing them at full T costs 3×
    4 B·T·di·ds bytes (4.3 GiB/layer on jamba-1.5-large) and was the
    dominant live buffer of the hybrid cells.  The ds axis is contracted
    inside the body too, so only (B, ch, di) leaves the chunk.
    dt: (B,T,di) f32;  b_in/c_in: (B,T,ds);  u: (B,T,di);  a: (di,ds)."""
    b, t, di = dt.shape
    ds = a.shape[1]
    ch = min(cfg.chunk, t)
    assert t % ch == 0, (t, ch)
    nc = t // ch

    def chunked(x):
        return x.reshape(b, nc, ch, *x.shape[2:]).transpose(
            1, 0, 2, *range(3, x.ndim + 1))

    xs = (chunked(dt), chunked(b_in), chunked(c_in), chunked(u))

    def chunk_body(h0, xs):
        dt_c, b_c, c_c, u_c = xs
        a_bar = jnp.exp(dt_c[..., None] * a[None, None])        # (B,ch,di,ds)
        bx = (dt_c[..., None] * b_c.astype(jnp.float32)[:, :, None, :]
              * u_c.astype(jnp.float32)[..., None])

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(op, (a_bar, bx), axis=1)
        h = a_cum * h0[:, None] + b_cum                         # (B,ch,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", h, c_c.astype(jnp.float32))
        return h[:, -1], y

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, xs, unroll=cfg.unroll)
    return ys.transpose(1, 0, 2, 3).reshape(b, t, di)


def ssm_apply(params: Params, x: jnp.ndarray, cfg: SSMConfig) -> jnp.ndarray:
    """Training/prefill path. x: (B, T, d_model)."""
    bsz, t, _ = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    uz = x @ params["w_in"]
    u, z = jnp.split(uz, 2, axis=-1)
    prefix = jnp.zeros((bsz, cfg.d_conv - 1, di), u.dtype)
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"], prefix))
    proj = u @ params["w_x"]
    dt_r, b_in, c_in = jnp.split(proj, [cfg.rank, cfg.rank + ds], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ params["w_dt"].astype(jnp.float32)
                         + params["dt_bias"])               # (B,T,di)
    a = -jnp.exp(params["a_log"])                           # (di, ds)
    y = _selective_scan_chunked(dt, b_in, c_in, u, a, cfg)
    y = y + params["d_skip"][None, None] * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["w_out"]


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def ssm_init_cache(cfg: SSMConfig, batch: int, dtype) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


def ssm_decode(params: Params, x: jnp.ndarray, cache: Params,
               cfg: SSMConfig) -> Tuple[jnp.ndarray, Params]:
    """x: (B, 1, d_model)."""
    bsz = x.shape[0]
    di, ds = cfg.d_inner, cfg.d_state
    uz = x @ params["w_in"]
    u, z = jnp.split(uz, 2, axis=-1)                        # (B,1,di)
    window = jnp.concatenate([cache["conv"], u], axis=1)    # (B,dc,di)
    u1 = (window * params["conv_w"][None]).sum(axis=1, keepdims=True) \
        + params["conv_b"][None, None]
    u1 = jax.nn.silu(u1)
    proj = u1 @ params["w_x"]
    dt_r, b_in, c_in = jnp.split(proj, [cfg.rank, cfg.rank + ds], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ params["w_dt"].astype(jnp.float32)
                         + params["dt_bias"])               # (B,1,di)
    a = -jnp.exp(params["a_log"])
    a_bar = jnp.exp(dt[0 if False else ...][..., None] * a[None, None])[:, 0]
    bx = (dt[..., None] * b_in.astype(jnp.float32)[:, :, None, :]
          * u1.astype(jnp.float32)[..., None])[:, 0]        # (B,di,ds)
    h = a_bar * cache["h"] + bx
    y = jnp.einsum("bds,bs->bd", h, c_in[:, 0].astype(jnp.float32))[:, None]
    y = y + params["d_skip"][None, None] * u1.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_cache = {"conv": window[:, 1:], "h": h}
    return y @ params["w_out"], new_cache
