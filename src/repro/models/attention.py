"""Attention: GQA / sliding-window / MLA, training + cached-decode paths.

Training attention is *blockwise* (flash-style online softmax over KV
chunks) so that lowering never materializes the (T×T) score matrix — a
hard requirement for the 32k prefill / 4k×256 train shapes.  Two schedules:

  rect — every q-chunk scans every kv-chunk, masked.  Simple, but the HLO
         carries ~2× the causal FLOPs.  (baseline)
  tri  — q-chunks are unrolled and each scans only its causal prefix of
         kv-chunks, so compiled FLOPs ≈ T²/2.  (used by §Perf hillclimb)

MLA (DeepSeek-V2) caches the 512-d latent + shared rope key; decode uses
the absorbed-projection form (q projected into latent space) so per-step
cost is O(S·(r + d_rope)) per head rather than O(S·2·d_head).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init

Params = Dict[str, Any]
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window size (None = full)
    # MLA
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # blockwise schedule
    q_chunk: int = 512
    kv_chunk: int = 512
    schedule: str = "rect"                # "rect" | "tri"
    unroll: int = 1                       # kv-chunk scan unrolling


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def attn_init(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    if cfg.use_mla:
        qd = cfg.qk_nope_dim + cfg.qk_rope_dim
        return {
            "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * qd, dtype),
            "wdkv": dense_init(ks[1], cfg.d_model, cfg.kv_lora_rank, dtype),
            "wkr": dense_init(ks[2], cfg.d_model, cfg.qk_rope_dim, dtype),
            "wuk": dense_init(ks[3], cfg.kv_lora_rank,
                              cfg.n_heads * cfg.qk_nope_dim, dtype),
            "wuv": dense_init(ks[4], cfg.kv_lora_rank,
                              cfg.n_heads * cfg.v_head_dim, dtype),
            "wo": dense_init(ks[5], cfg.n_heads * cfg.v_head_dim,
                             cfg.d_model, dtype),
        }
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# Blockwise (flash-style) multi-head attention core
# ---------------------------------------------------------------------------

def _chunk_attn_step(q, k, v, qpos, kpos, *, causal, window, scale):
    """One (q-chunk × kv-chunk) tile: returns (scores-exp, max, out-partial).

    q: (B, Tq, Hk, G, D); k/v: (B, Tk, Hk, D)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    # base mask: padded keys carry kpos = 2**30 and must never attend
    mask = jnp.broadcast_to((kpos < 2 ** 29)[None, :],
                            (q.shape[1], k.shape[1]))
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                             # (B,H,G,Tq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return m, l, o


def blockwise_attention(
    q: jnp.ndarray,                # (B, T, Hq, D)
    k: jnp.ndarray,                # (B, S, Hk, D)
    v: jnp.ndarray,                # (B, S, Hk, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    schedule: str = "rect",
    q_offset: int = 0,             # absolute position of q[0] (for caches)
    unroll: int = 1,
) -> jnp.ndarray:
    b, t, hq, d = q.shape
    _, s, hk, dv = v.shape
    g = hq // hk
    scale = d ** -0.5
    q = q.reshape(b, t, hk, g, d)
    q_chunk = min(q_chunk, t)
    kv_chunk = min(kv_chunk, s)
    nq = -(-t // q_chunk)
    nk = -(-s // kv_chunk)
    # pad to chunk multiples
    tp, sp = nq * q_chunk, nk * kv_chunk
    if tp != t:
        q = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0), (0, 0)))
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    qpos_all = q_offset + jnp.arange(tp)
    kpos_all = jnp.arange(sp)
    kpos_all = jnp.where(kpos_all < s, kpos_all, 2 ** 30)  # pad keys masked out

    kc = k.reshape(b, nk, kv_chunk, hk, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, hk, dv).transpose(1, 0, 2, 3, 4)
    kposc = kpos_all.reshape(nk, kv_chunk)

    @functools.partial(jax.checkpoint, prevent_cse=False, static_argnums=(2,))
    def one_q_chunk(qi, qpos, n_kv):
        """Online-softmax over the first ``n_kv`` kv chunks (static)."""
        def body(carry, xs):
            m_acc, l_acc, o_acc = carry
            kj, vj, kpos = xs
            m, l, o = _chunk_attn_step(qi, kj, vj, qpos, kpos,
                                       causal=causal, window=window,
                                       scale=scale)
            m_new = jnp.maximum(m_acc, m)
            c1 = jnp.exp(m_acc - m_new)
            c2 = jnp.exp(m - m_new)
            return (m_new, l_acc * c1 + l * c2,
                    o_acc * c1[..., None] + o * c2[..., None]), None

        m0 = jnp.full((b, hk, g, qi.shape[1]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, qi.shape[1]), jnp.float32)
        o0 = jnp.zeros((b, hk, g, qi.shape[1], dv), jnp.float32)
        (m_f, l_f, o_f), _ = jax.lax.scan(
            body, (m0, l0, o0), (kc[:n_kv], vc[:n_kv], kposc[:n_kv]),
            unroll=unroll)
        out = o_f / jnp.maximum(l_f[..., None], 1e-30)
        return out                                       # (B,Hk,G,Tq,Dv)

    outs = []
    for i in range(nq):
        qi = jax.lax.slice_in_dim(q, i * q_chunk, (i + 1) * q_chunk, axis=1)
        qpos = jax.lax.slice_in_dim(qpos_all, i * q_chunk, (i + 1) * q_chunk)
        if schedule == "tri" and causal and q_offset == 0:
            # causal prefix only: kv chunks [0 .. ceil(((i+1)*q_chunk)/kv_chunk))
            n_kv = min(nk, -(-((i + 1) * q_chunk) // kv_chunk))
        else:
            n_kv = nk
        outs.append(one_q_chunk(qi, qpos, n_kv))
    out = jnp.concatenate(outs, axis=3)                  # (B,Hk,G,Tp,Dv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, tp, hq, dv)
    return out[:, :t].astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA self-attention (training path)
# ---------------------------------------------------------------------------

def attn_apply(params: Params, x: jnp.ndarray, cfg: AttnConfig,
               positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)
    if cfg.use_mla:
        return _mla_apply(params, x, cfg, positions)
    q = (x @ params["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = blockwise_attention(q, k, v, causal=True, window=cfg.window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                            schedule=cfg.schedule, unroll=cfg.unroll)
    return o.reshape(b, t, -1) @ params["wo"]


def _mla_apply(params: Params, x: jnp.ndarray, cfg: AttnConfig,
               positions: jnp.ndarray) -> jnp.ndarray:
    b, t, _ = x.shape
    h = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = (x @ params["wq"]).reshape(b, t, h, qd)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = x @ params["wdkv"]                              # (B,T,r)
    k_rope = apply_rope((x @ params["wkr"])[:, :, None, :],
                        positions, cfg.rope_theta)         # (B,T,1,dr)
    k_nope = (c_kv @ params["wuk"]).reshape(b, t, h, cfg.qk_nope_dim)
    vv = (c_kv @ params["wuv"]).reshape(b, t, h, cfg.v_head_dim)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, t, h, cfg.qk_rope_dim))], axis=-1)
    o = blockwise_attention(qf, kf, vv, causal=True,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                            schedule=cfg.schedule, unroll=cfg.unroll)
    return o.reshape(b, t, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: AttnConfig, d_memory: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim, dtype),
        "wk": dense_init(ks[1], d_memory, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wv": dense_init(ks[2], d_memory, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }


def cross_attn_apply(params: Params, x: jnp.ndarray, memory: jnp.ndarray,
                     cfg: AttnConfig) -> jnp.ndarray:
    b, t, _ = x.shape
    s = memory.shape[1]
    q = (x @ params["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = (memory @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (memory @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    o = blockwise_attention(q, k, v, causal=False,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    return o.reshape(b, t, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# Decode path with KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype) -> Params:
    if cfg.use_mla:
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        }
    length = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def attn_decode(params: Params, x: jnp.ndarray, cache: Params,
                index: jnp.ndarray, cfg: AttnConfig) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. x: (B, 1, d); index: scalar current position."""
    b = x.shape[0]
    pos = jnp.full((1,), index)
    if cfg.use_mla:
        return _mla_decode(params, x, cache, index, cfg)
    q = (x @ params["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    slot = index % cache["k"].shape[1] if cfg.window else index
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
    s_len = ck.shape[1]
    kpos = jnp.arange(s_len)
    if cfg.window:  # ring buffer: absolute position of each slot
        wrap = (index // s_len) * s_len
        kpos = jnp.where(kpos <= index % s_len, wrap + kpos, wrap - s_len + kpos)
    valid = (kpos <= index) & (kpos >= 0)
    if cfg.window:
        valid &= index - kpos < cfg.window
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.head_dim)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) * cfg.head_dim ** -0.5
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, cv.astype(jnp.float32))
    o = o.reshape(b, 1, -1).astype(x.dtype)
    return o @ params["wo"], {"k": ck, "v": cv}


def _mla_decode(params: Params, x: jnp.ndarray, cache: Params,
                index: jnp.ndarray, cfg: AttnConfig) -> Tuple[jnp.ndarray, Params]:
    """Absorbed-projection MLA decode: score/value both in latent space."""
    b = x.shape[0]
    h = cfg.n_heads
    pos = jnp.full((1,), index)
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = (x @ params["wq"]).reshape(b, 1, h, qd)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    c_new = x @ params["wdkv"]                             # (B,1,r)
    kr_new = apply_rope((x @ params["wkr"])[:, :, None, :], pos,
                        cfg.rope_theta)[:, :, 0, :]        # (B,1,dr)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), index, 1)
    ckr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), index, 1)
    # absorb W_uk into q:  q_lat (B,1,H,r)
    wuk = params["wuk"].reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    s_len = ckv.shape[1]
    valid = jnp.arange(s_len) <= index
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           ckr.astype(jnp.float32))) * (qd ** -0.5)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", p, ckv.astype(jnp.float32))
    wuv = params["wuv"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, wuv.astype(jnp.float32))
    o = o.reshape(b, 1, -1).astype(x.dtype)
    return o @ params["wo"], {"c_kv": ckv, "k_rope": ckr}
