"""Decoder LM / encoder-decoder assembly with scanned layer periods.

The layer stack is grouped into repeating *periods* (cfg.pattern); params
and caches are stacked over periods so the whole stack lowers to a single
``lax.scan`` — which keeps HLO size O(period) instead of O(n_layers) for
the 512-device dry-run compiles, and gives the standard remat point.

Paths:
  lm_loss      — training: tokens → chunked-vocab xent (+ MoE aux)
  lm_hidden    — shared trunk
  decode_step  — single-token serve step over per-layer caches
  encode       — encoder trunk (enc-dec archs); decoder cross-attends
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constraint
from .attention import (AttnConfig, attn_apply, attn_decode, attn_init,
                        blockwise_attention, cross_attn_apply,
                        cross_attn_init, init_cache as attn_init_cache)
from .common import dense_init, embed_init, make_norm
from .ffn import ffn_apply, ffn_init
from .moe import moe_apply, moe_init
from .ssm import ssm_apply, ssm_decode, ssm_init, ssm_init_cache
from .xlstm import (mlstm_apply, mlstm_decode, mlstm_init, mlstm_init_cache,
                    slstm_apply, slstm_decode, slstm_init, slstm_init_cache)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Single block (one layer): init / train-apply / decode-apply
# ---------------------------------------------------------------------------

def _block_attn_cfg(cfg, kind: str) -> AttnConfig:
    window = cfg.sliding_window if kind == "L" else None
    return cfg.attn_config(window=window)


def block_init(key, cfg, kind: str, use_moe: bool, *, cross: bool = False,
               dtype=jnp.float32) -> Params:
    ninit, _ = make_norm(cfg.norm)
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": ninit(cfg.d_model, dtype)}
    if kind in "ALG":
        p["attn"] = attn_init(ks[0], _block_attn_cfg(cfg, kind), dtype)
    elif kind == "M":
        p["ssm"] = ssm_init(ks[0], cfg.ssm_config(), dtype)
    elif kind == "m":
        p["mlstm"] = mlstm_init(ks[0], cfg.xlstm_config(), dtype)
        return p                                   # self-contained block
    elif kind == "s":
        p["slstm"] = slstm_init(ks[0], cfg.xlstm_config(), dtype)
        return p
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = ninit(cfg.d_model, dtype)
        p["cross"] = cross_attn_init(ks[2], cfg.attn_config(), cfg.d_model, dtype)
    p["norm2"] = ninit(cfg.d_model, dtype)
    if use_moe:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.moe, dtype)
    else:
        p["ffn"] = ffn_init(ks[1], cfg.ffn_config(), dtype)
    return p


def block_apply(p: Params, x: jnp.ndarray, cfg, kind: str, use_moe: bool,
                *, causal: bool = True, memory: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/prefill path; returns (x, moe_aux)."""
    _, norm = make_norm(cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    h = norm(p["norm1"], x)
    if kind in "ALG":
        acfg = _block_attn_cfg(cfg, kind)
        if causal:
            h = attn_apply(p["attn"], h, acfg)
        else:  # encoder: bidirectional full attention
            b, t, _ = h.shape
            q = (h @ p["attn"]["wq"]).reshape(b, t, acfg.n_heads, acfg.head_dim)
            k = (h @ p["attn"]["wk"]).reshape(b, t, acfg.n_kv_heads, acfg.head_dim)
            v = (h @ p["attn"]["wv"]).reshape(b, t, acfg.n_kv_heads, acfg.head_dim)
            from .common import apply_rope
            pos = jnp.arange(t)
            q = apply_rope(q, pos, acfg.rope_theta)
            k = apply_rope(k, pos, acfg.rope_theta)
            o = blockwise_attention(q, k, v, causal=False,
                                    q_chunk=acfg.q_chunk, kv_chunk=acfg.kv_chunk)
            h = o.reshape(b, t, -1) @ p["attn"]["wo"]
    elif kind == "M":
        h = ssm_apply(p["ssm"], h, cfg.ssm_config())
    elif kind == "m":
        return x + mlstm_apply(p["mlstm"], h, cfg.xlstm_config()), aux
    elif kind == "s":
        return x + slstm_apply(p["slstm"], h, cfg.xlstm_config()), aux
    x = x + h
    x = constraint(x, "act_btd")
    if memory is not None and "cross" in p:
        h = norm(p["norm_x"], x)
        x = x + cross_attn_apply(p["cross"], h, memory, cfg.attn_config())
    h = norm(p["norm2"], x)
    if use_moe:
        h, aux = moe_apply(p["moe"], h, cfg.moe)
    else:
        h = ffn_apply(p["ffn"], h, cfg.ffn_config())
    x = x + h
    return constraint(x, "act_btd"), aux


def block_init_cache(cfg, kind: str, batch: int, max_len: int, dtype) -> Params:
    if kind in "ALG":
        return attn_init_cache(_block_attn_cfg(cfg, kind), batch, max_len, dtype)
    if kind == "M":
        return ssm_init_cache(cfg.ssm_config(), batch, dtype)
    if kind == "m":
        return mlstm_init_cache(cfg.xlstm_config(), batch)
    if kind == "s":
        return slstm_init_cache(cfg.xlstm_config(), batch)
    raise ValueError(kind)


def block_decode(p: Params, x: jnp.ndarray, cache: Params, index, cfg,
                 kind: str, use_moe: bool,
                 memory: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Params]:
    _, norm = make_norm(cfg.norm)
    h = norm(p["norm1"], x)
    if kind in "ALG":
        h, cache = attn_decode(p["attn"], h, cache, index, _block_attn_cfg(cfg, kind))
    elif kind == "M":
        h, cache = ssm_decode(p["ssm"], h, cache, cfg.ssm_config())
    elif kind == "m":
        h, cache = mlstm_decode(p["mlstm"], h, cache, cfg.xlstm_config())
        return x + h, cache
    elif kind == "s":
        h, cache = slstm_decode(p["slstm"], h, cache, cfg.xlstm_config())
        return x + h, cache
    x = x + h
    if memory is not None and "cross" in p:
        h = norm(p["norm_x"], x)
        x = x + cross_attn_apply(p["cross"], h, memory, cfg.attn_config())
    h = norm(p["norm2"], x)
    if use_moe:
        h, _ = moe_apply(p["moe"], h, cfg.moe)
    else:
        h = ffn_apply(p["ffn"], h, cfg.ffn_config())
    return x + h, cache


# ---------------------------------------------------------------------------
# Stack assembly
# ---------------------------------------------------------------------------

def _embed_lookup(embed: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup that stays efficient under vocab sharding.

    XLA's SPMD partitioner lowers a gather from a vocab-sharded table into
    per-element u32 select masks — measured at 16–20 GiB PER TENSOR on the
    8192-d/64k-vocab cells.  When the launcher installs a mesh in the
    sharding rules ("__mesh__" + "embed_vocab_axis"), we instead shard_map
    the textbook pattern: local gather of the owned vocab slice, mask,
    psum over the vocab axis.  Exact same math; collective is one psum of
    the (tokens × d) output.
    """
    from repro.sharding.context import current_rules
    rules = current_rules() or {}
    mesh = rules.get("__mesh__")
    vaxis = rules.get("embed_vocab_axis")
    if mesh is None or vaxis is None:
        return embed[tokens]
    from jax.sharding import PartitionSpec as P
    v, d = embed.shape
    n = mesh.shape[vaxis]
    if n <= 1 or v % n != 0:
        return embed[tokens]
    vs = v // n
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    tok_spec = P(dp, *([None] * (tokens.ndim - 1))) \
        if tokens.shape[0] % dp_n == 0 else P(*([None] * tokens.ndim))

    def f(emb, toks):
        lo = jax.lax.axis_index(vaxis) * vs
        idx = toks - lo
        ok = (idx >= 0) & (idx < vs)
        safe = jnp.clip(idx, 0, vs - 1)
        out = emb[safe] * ok[..., None].astype(emb.dtype)
        return jax.lax.psum(out, vaxis)

    out_spec = P(*tok_spec, None)
    # jax.shard_map is only a top-level name on newer jax; fall back to the
    # experimental location that jax 0.4.x ships.
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh,
                     in_specs=(P(vaxis, None), tok_spec),
                     out_specs=out_spec)(embed, tokens)


def _period_layout(cfg) -> Tuple[Tuple[str, bool], ...]:
    """(kind, use_moe) per position within one period of the decoder."""
    kinds = cfg.layer_kinds()
    period = len(cfg.pattern)
    start = cfg.n_dense_layers
    out = []
    for pos in range(period):
        idx = start + pos
        out.append((kinds[idx], cfg.layer_uses_moe(idx)))
    return tuple(out)


def lm_init(key, cfg, dtype=None) -> Params:
    from .common import dtype_of
    dtype = dtype or dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    ninit, _ = make_norm(cfg.norm)
    p: Params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
    if cfg.frontend:
        p["frontend_proj"] = dense_init(keys[1], cfg.frontend_dim or cfg.d_model,
                                        cfg.d_model, dtype)
    # unrolled dense prefix (e.g. deepseek layer 0)
    if cfg.n_dense_layers:
        kinds = cfg.layer_kinds()
        pk = jax.random.split(keys[2], cfg.n_dense_layers)
        p["prefix"] = [block_init(pk[i], cfg, kinds[i], False, dtype=dtype)
                       for i in range(cfg.n_dense_layers)]
    # scanned periods
    layout = _period_layout(cfg)
    n_periods = (cfg.n_layers - cfg.n_dense_layers) // len(cfg.pattern)
    cross = cfg.enc_dec
    pkeys = jax.random.split(keys[3], n_periods)
    p["layers"] = {}
    for pos, (kind, use_moe) in enumerate(layout):
        init_one = lambda k, kind=kind, um=use_moe: block_init(
            k, cfg, kind, um, cross=cross, dtype=dtype)
        p["layers"][f"b{pos}"] = jax.vmap(init_one)(pkeys)
    p["final_norm"] = ninit(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[4], cfg.d_model, cfg.vocab_size, dtype)
    # encoder stack (enc-dec)
    if cfg.enc_dec:
        ekeys = jax.random.split(keys[5], cfg.n_enc_layers + 1)
        p["encoder"] = {
            "layers": [block_init(ekeys[i], cfg, cfg.enc_pattern[i % len(cfg.enc_pattern)],
                                  False, dtype=dtype)
                       for i in range(cfg.n_enc_layers)],
            "final_norm": ninit(cfg.d_model, dtype),
        }
    return p


def encode(params: Params, frontend_embeds: jnp.ndarray, cfg) -> jnp.ndarray:
    """Encoder trunk over stub frontend embeddings (B, F, frontend_dim)."""
    _, norm = make_norm(cfg.norm)
    x = frontend_embeds
    if "frontend_proj" in params:
        x = x @ params["frontend_proj"]
    for i, lp in enumerate(params["encoder"]["layers"]):
        kind = cfg.enc_pattern[i % len(cfg.enc_pattern)]
        layer = lambda lp, x: block_apply(lp, x, cfg, kind, False,
                                          causal=False)
        if cfg.remat:
            # unrolled stack: without per-layer remat the encoder keeps
            # every intermediate live through the decoder's backward
            # (measured +30 GiB on seamless train_4k)
            layer = jax.checkpoint(layer, prevent_cse=False)
        x, _ = layer(lp, x)
    return norm(params["encoder"]["final_norm"], x)


def lm_hidden(params: Params, tokens: jnp.ndarray, cfg,
              frontend_embeds: Optional[jnp.ndarray] = None,
              memory: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, T) → hidden (B, T', d), plus accumulated MoE aux loss."""
    x = _embed_lookup(params["embed"], tokens)
    if frontend_embeds is not None and not cfg.enc_dec:
        fe = frontend_embeds
        if "frontend_proj" in params:
            fe = fe @ params["frontend_proj"]
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
    x = constraint(x, "act_btd")
    aux = jnp.zeros((), jnp.float32)
    for i in range(cfg.n_dense_layers):
        kinds = cfg.layer_kinds()
        x, a = block_apply(params["prefix"][i], x, cfg, kinds[i], False,
                           memory=memory)
        aux += a

    layout = _period_layout(cfg)

    def period_body(x, layer_params):
        aux_p = jnp.zeros((), jnp.float32)
        for pos, (kind, use_moe) in enumerate(layout):
            x, a = block_apply(layer_params[f"b{pos}"], x, cfg, kind, use_moe,
                               memory=memory)
            aux_p += a
        return x, aux_p

    if cfg.remat:
        period_body = jax.checkpoint(period_body, prevent_cse=False)

    def scan_body(carry, layer_params):
        x, aux = carry
        # "act_stash" (installed by the launcher for ≥100B cells) shards
        # the period-boundary residual over the model axis BEFORE it is
        # saved as the remat stash — the stash is the dominant live buffer
        # at 64–72 layers, and this pins it at 1/model_n size for one
        # all-gather per period per direction.
        x = constraint(x, "act_stash")
        x, a = period_body(x, layer_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, aux), params["layers"],
                               unroll=cfg.scan_unroll)
    _, norm = make_norm(cfg.norm)
    return norm(params["final_norm"], x), aux


def lm_head_weight(params: Params, cfg) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constraint(w, "head_dv")


def chunked_xent(h2d: jnp.ndarray, targets: jnp.ndarray, w_head: jnp.ndarray,
                 *, chunk: int = 4096, unroll: int = 1) -> jnp.ndarray:
    """Mean next-token xent without materializing (T, V) logits.

    h2d: (T, d); targets: (T,) with -1 = pad; w_head: (d, V)."""
    t = h2d.shape[0]
    chunk = min(chunk, t)
    pad = -t % chunk
    if pad:
        h2d = jnp.pad(h2d, ((0, pad), (0, 0)))
        targets = jnp.pad(targets, (0, pad), constant_values=-1)
    nchunk = h2d.shape[0] // chunk
    hc = h2d.reshape(nchunk, chunk, -1)
    tc = targets.reshape(nchunk, chunk)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one(hx, tx):
        logits = hx.astype(jnp.float32) @ w_head.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(tx, 0)[:, None], axis=-1)[:, 0]
        valid = (tx >= 0).astype(jnp.float32)
        return ((lse - tgt) * valid).sum(), valid.sum()

    def body(carry, xs):
        l, n = one(*xs)
        return (carry[0] + l, carry[1] + n), None

    (loss_sum, n_valid), _ = jax.lax.scan(body, (0.0, 0.0), (hc, tc),
                                          unroll=unroll)
    return loss_sum / jnp.maximum(n_valid, 1.0)


def lm_loss(params: Params, batch: Dict[str, jnp.ndarray], cfg) -> jnp.ndarray:
    """batch: tokens (B, T) [+ frontend_embeds]; next-token LM loss."""
    memory = None
    fe = batch.get("frontend_embeds")
    if cfg.enc_dec:
        memory = encode(params, fe, cfg)
        fe = None
    h, aux = lm_hidden(params, batch["tokens"][:, :-1], cfg,
                       frontend_embeds=fe, memory=memory)
    targets = batch["tokens"][:, 1:]
    if fe is not None:
        # frontend positions are prepended; no LM targets for them
        b, f = fe.shape[0], fe.shape[1]
        pad = jnp.full((b, f), -1, targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    d = h.shape[-1]
    loss = chunked_xent(h.reshape(-1, d), targets.reshape(-1),
                        lm_head_weight(params, cfg), unroll=cfg.scan_unroll)
    return loss + aux


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int, dtype) -> Params:
    kinds = cfg.layer_kinds()
    caches: Params = {}
    if cfg.n_dense_layers:
        caches["prefix"] = [
            block_init_cache(cfg, kinds[i], batch, max_len, dtype)
            for i in range(cfg.n_dense_layers)]
    layout = _period_layout(cfg)
    n_periods = (cfg.n_layers - cfg.n_dense_layers) // len(cfg.pattern)
    caches["layers"] = {}
    for pos, (kind, _) in enumerate(layout):
        one = block_init_cache(cfg, kind, batch, max_len, dtype)
        caches["layers"][f"b{pos}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one)
    return caches


def decode_step(params: Params, token: jnp.ndarray, caches: Params,
                index: jnp.ndarray, cfg,
                memory: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Params]:
    """token (B,) int32 → logits (B, V); updates caches functionally."""
    x = _embed_lookup(params["embed"], token)[:, None, :]   # (B, 1, d)
    kinds = cfg.layer_kinds()
    new_caches: Params = {}
    if cfg.n_dense_layers:
        new_caches["prefix"] = []
        for i in range(cfg.n_dense_layers):
            x, c = block_decode(params["prefix"][i], x, caches["prefix"][i],
                                index, cfg, kinds[i], False, memory=memory)
            new_caches["prefix"].append(c)

    layout = _period_layout(cfg)

    def scan_body(x, xs):
        layer_params, layer_cache = xs
        new_cache = {}
        for pos, (kind, use_moe) in enumerate(layout):
            x, c = block_decode(layer_params[f"b{pos}"], x,
                                layer_cache[f"b{pos}"], index, cfg, kind,
                                use_moe, memory=memory)
            new_cache[f"b{pos}"] = c
        return x, new_cache

    x, new_layer_caches = jax.lax.scan(
        scan_body, x, (params["layers"], caches["layers"]),
        unroll=cfg.scan_unroll)
    new_caches["layers"] = new_layer_caches
    _, norm = make_norm(cfg.norm)
    h = norm(params["final_norm"], x)[:, 0]         # (B, d)
    logits = h.astype(jnp.float32) @ lm_head_weight(params, cfg).astype(jnp.float32)
    return logits, new_caches
