"""The paper's five CNN benchmarks (VGG16, ResNet18, GoogLeNet, DenseNet121,
MobileNet) built on core.sparse_conv's relu_conv/conv units, plus the trace
capture used to drive the cost model — mirroring the paper's §5 methodology
(layer-wise activation/gradient traces from real framework training).

Models are expressed as layer graphs of a small IR (ConvNode etc.) so that
one definition yields (a) the runnable JAX forward/backward, (b) the
ConvSpec list for the cost model, and (c) per-layer trace hooks.  Spatial
sizes are configurable: ``image_size=224`` gives the paper's ImageNet
geometry (for cost-model shape fidelity), smaller sizes give CPU-friendly
smoke/training configs.  Only representative blocks of the big nets are
repeated in reduced variants, exactly like the paper reports representative
blocks (Inception-3b, ResNet block-2, Dense-block-1).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import DC, SparsityPolicy
from repro.core.sparse_conv import (
    conv as sconv, depthwise_conv, depthwise_relu_conv, relu_conv,
)
from repro.core.sparse_linear import matmul as smatmul
from repro.core.costmodel import ConvSpec
from repro.kernels import stats

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Layer IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ConvNode:
    name: str
    out_ch: int
    kernel: int
    stride: int = 1
    padding: str = "SAME"
    has_bn: bool = False
    relu_after: bool = True       # (BN+)ReLU after this conv
    depthwise: bool = False


@dataclasses.dataclass
class PoolNode:
    name: str
    kind: str                     # "max" | "avg"
    size: int = 2
    stride: int = 2


@dataclasses.dataclass
class Trace:
    """Per-conv-layer tensors captured during one training step."""
    name: str
    act_out: jnp.ndarray          # post-(BN+)ReLU output feature map (NHWC)
    grad_out: jnp.ndarray         # gradient at the same point (post-Hadamard)
    input_act: jnp.ndarray        # the conv's input (post-ReLU of producer)
    grad_in: jnp.ndarray          # gradient arriving at the conv's output


def resolved_out_ch(node: ConvNode, in_ch: int) -> int:
    """Depthwise output width follows the input; the IR leaves it 0 until a
    walk supplies the producer's channel count.  Pure — the IR is never
    mutated, so init / conv_specs / re-init in any order agree."""
    return in_ch if node.depthwise else node.out_ch


def conv_init(key, node: ConvNode, in_ch: int, dtype=jnp.float32) -> Params:
    k = node.kernel
    c = 1 if node.depthwise else in_ch
    out_ch = resolved_out_ch(node, in_ch)
    fan_in = k * k * c
    w = jax.random.normal(key, (k, k, c, out_ch), jnp.float32) \
        * (2.0 / fan_in) ** 0.5
    p: Params = {"w": w.astype(dtype)}
    if node.has_bn:
        p["bn_scale"] = jnp.ones((out_ch,), jnp.float32)
        p["bn_bias"] = jnp.zeros((out_ch,), jnp.float32)
    return p


def batchnorm(x: jnp.ndarray, scale, bias, eps=1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def apply_conv(p: Params, x_pre: jnp.ndarray, node: ConvNode,
               policy: SparsityPolicy, input_is_relu: bool) -> jnp.ndarray:
    """x_pre is PRE-activation of the producer if input_is_relu (the fused
    relu_conv consumes it), else the raw input."""
    if node.depthwise:
        if p["w"].shape[2] != 1 or x_pre.shape[-1] != p["w"].shape[3]:
            # Defensive escape hatch for malformed group structure; counted
            # (and scope-tagged for the static analyzer) so audits can
            # assert the sparse path never loses a layer.
            stats.record("conv:dense_fallback")
            with stats.lifecycle_scope("fallback", "conv_dense"):
                x = jnp.maximum(x_pre, 0) if input_is_relu else x_pre
                y = jax.lax.conv_general_dilated(
                    x, p["w"], (node.stride, node.stride), node.padding,
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    feature_group_count=x.shape[-1])
        elif input_is_relu:
            # depthwise through the sparse unit: groups == C, fused encode —
            # the dw→pw chain keeps the pre-activation contract end to end.
            y = depthwise_relu_conv(x_pre, p["w"], node.stride, node.padding,
                                    policy)
        else:
            y = depthwise_conv(x_pre, p["w"], node.stride, node.padding,
                               policy)
    elif input_is_relu:
        y = relu_conv(x_pre, p["w"], node.stride, node.padding, policy)
    else:
        y = sconv(x_pre, p["w"], node.stride, node.padding, policy)
    if node.has_bn:
        y = batchnorm(y, p["bn_scale"], p["bn_bias"])
    return y


def apply_pool(x: jnp.ndarray, node: PoolNode) -> jnp.ndarray:
    if node.kind == "max":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, node.size, node.size, 1),
            (1, node.stride, node.stride, 1), "SAME")
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, node.size, node.size, 1),
        (1, node.stride, node.stride, 1), "SAME")
    return s / (node.size * node.size)


# ---------------------------------------------------------------------------
# Network definitions (sequential IR with branch support for blocks)
# ---------------------------------------------------------------------------

def vgg16_layers(width: float = 1.0) -> List[Any]:
    def c(n, ch, **kw):
        return ConvNode(n, int(ch * width), 3, **kw)
    return [
        c("conv1", 64), c("conv2", 64), PoolNode("pool1", "max"),
        c("conv3", 128), c("conv4", 128), PoolNode("pool2", "max"),
        c("conv5", 256), c("conv6", 256), c("conv7", 256), PoolNode("pool3", "max"),
        c("conv8", 512), c("conv9", 512), c("conv10", 512), PoolNode("pool4", "max"),
        c("conv11", 512), c("conv12", 512), c("conv13", 512), PoolNode("pool5", "max"),
    ]


def mobilenet_layers(width: float = 1.0) -> List[Any]:
    """Linear dw/pw stack (paper evaluates the pw convs)."""
    out: List[Any] = [ConvNode("conv0", int(32 * width), 3, stride=2, has_bn=True)]
    chans = [64, 128, 128, 256, 256, 512, 512, 512, 512, 512, 512, 1024, 1024]
    strides = [1, 2, 1, 2, 1, 2, 1, 1, 1, 1, 1, 2, 1]
    for i, (ch, st) in enumerate(zip(chans, strides)):
        out.append(ConvNode(f"dw{i+1}", 0, 3, stride=st, has_bn=True,
                            depthwise=True))
        out.append(ConvNode(f"pw{i+1}", int(ch * width), 1, has_bn=True))
    return out


@dataclasses.dataclass
class Branch:
    name: str
    paths: List[List[Any]]        # parallel sub-sequences
    merge: str                    # "concat" | "add"


def googlenet_inception3b(width: float = 1.0) -> List[Any]:
    """Inception-3b (paper Fig. 3a): 4 parallel paths, concat merge, no BN."""
    w = lambda ch: int(ch * width)
    return [
        ConvNode("pre", w(192), 3, has_bn=False),
        PoolNode("pool1", "max"),
        Branch("incep3b", [
            [ConvNode("conv11", w(64), 1)],
            [ConvNode("conv33r", w(96), 1), ConvNode("conv33", w(128), 3)],
            [ConvNode("conv55r", w(16), 1), ConvNode("conv55", w(32), 5)],
            [PoolNode("bpool", "max", 3, 1), ConvNode("convpp", w(32), 1)],
        ], merge="concat"),
    ]


def resnet18_block2(width: float = 1.0) -> List[Any]:
    """Residual block-2 region (paper Fig. 13/14): BN nets → OUT-only in BP."""
    w = lambda ch: int(ch * width)
    return [
        ConvNode("stem", w(64), 3, stride=2, has_bn=True),
        Branch("res1", [
            [ConvNode("b1conv1", w(128), 3, stride=2, has_bn=True),
             ConvNode("b1conv2", w(128), 3, has_bn=True, relu_after=False)],
            [ConvNode("b1skip", w(128), 1, stride=2, has_bn=True,
                      relu_after=False)],
        ], merge="add"),
        Branch("res2", [
            [ConvNode("b2conv1", w(128), 3, has_bn=True),
             ConvNode("b2conv2", w(128), 3, has_bn=True, relu_after=False)],
            [],
        ], merge="add"),
    ]


def densenet_block1(width: float = 1.0, growth: int = 32, reps: int = 6) -> List[Any]:
    """Dense-block-1 (paper Fig. 12a): concat merges retain sparsity."""
    g = max(8, int(growth * width))
    out: List[Any] = [ConvNode("stem", int(64 * width), 3, stride=2, has_bn=True)]
    for i in range(reps):
        out.append(Branch(f"dense{i+1}", [
            [ConvNode(f"d{i+1}c1", 4 * g, 1, has_bn=True),
             ConvNode(f"d{i+1}c3", g, 3, has_bn=True)],
            [],
        ], merge="concat"))
    return out


NETWORKS: Dict[str, Callable[..., List[Any]]] = {
    "vgg16": vgg16_layers,
    "googlenet": googlenet_inception3b,
    "resnet18": resnet18_block2,
    "densenet121": densenet_block1,
    "mobilenet": mobilenet_layers,
}


# ---------------------------------------------------------------------------
# Build / run
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CNNModel:
    name: str
    layers: List[Any]
    num_classes: int
    image_size: int
    in_ch: int = 3

    def init(self, key, dtype=jnp.float32) -> Params:
        params: Params = {}
        keys = iter(jax.random.split(key, 256))

        def walk(nodes, in_ch):
            for node in nodes:
                if isinstance(node, ConvNode):
                    params[node.name] = conv_init(next(keys), node, in_ch, dtype)
                    in_ch = resolved_out_ch(node, in_ch)
                elif isinstance(node, PoolNode):
                    pass
                elif isinstance(node, Branch):
                    outs = []
                    for path in node.paths:
                        outs.append(walk(path, in_ch))
                    in_ch = sum(outs) if node.merge == "concat" else outs[0]
            return in_ch

        final_ch = walk(self.layers, self.in_ch)
        params["head"] = {
            "w": (jax.random.normal(next(keys), (final_ch, self.num_classes),
                                    jnp.float32) * final_ch ** -0.5).astype(dtype)}
        return params

    def apply(self, params: Params, images: jnp.ndarray,
              policy: SparsityPolicy = DC,
              capture: Optional[Dict[str, jnp.ndarray]] = None) -> jnp.ndarray:
        """images: (N, H, W, C) → logits.  ``capture`` (if a dict) is filled
        with post-ReLU activations per conv layer name."""

        def run(nodes, x, input_is_relu):
            # x is raw input if not input_is_relu, else PRE-activation
            for node in nodes:
                if isinstance(node, ConvNode):
                    with stats.layer_scope(node.name):
                        x = apply_conv(params[node.name], x, node, policy,
                                       input_is_relu)
                    input_is_relu = node.relu_after
                    if capture is not None:
                        capture[node.name] = jnp.maximum(x, 0) \
                            if node.relu_after else x
                elif isinstance(node, PoolNode):
                    if input_is_relu:
                        x = jnp.maximum(x, 0)
                        input_is_relu = False
                    x = apply_pool(x, node)
                elif isinstance(node, Branch):
                    if input_is_relu:
                        x = jnp.maximum(x, 0)
                        input_is_relu = False
                    outs = []
                    for path in node.paths:
                        y, y_relu = run(path, x, False)
                        if y_relu:
                            y = jnp.maximum(y, 0)
                        outs.append(y)
                    x = jnp.concatenate(outs, -1) if node.merge == "concat" \
                        else functools.reduce(jnp.add, outs)
                    if node.merge == "add":
                        # post-merge ReLU (ResNet): re-enters pre-act domain
                        if capture is not None:
                            capture[node.name] = jnp.maximum(x, 0)
                        input_is_relu = True
            return x, input_is_relu

        x, is_relu = run(self.layers, images, False)
        if is_relu:
            x = jnp.maximum(x, 0)
        x = jnp.mean(x, axis=(1, 2))             # global average pool
        # Head GEMM through the sparse-aware unit: the pooled feature (post
        # global-mean, so typically dense) contributes no FP skipping, but
        # its bitmap is computed once and threaded to the WG stage, and the
        # incoming logit gradient's masks are shared across both backward
        # GEMMs — same metadata contract as every conv layer.
        with stats.layer_scope("head"):
            return smatmul(x, params["head"]["w"], policy)

    def loss(self, params: Params, images, labels,
             policy: SparsityPolicy = DC) -> jnp.ndarray:
        logits = self.apply(params, images, policy)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    # -- cost-model bridge --
    def conv_specs(self, batch: int) -> List[ConvSpec]:
        """Static ConvSpec list at this model's geometry (input_is_relu /
        has_bn flags follow the graph, as the paper's applicability rules)."""
        specs: List[ConvSpec] = []

        def walk(nodes, in_ch, hw, input_is_relu):
            for node in nodes:
                if isinstance(node, ConvNode):
                    out_ch = resolved_out_ch(node, in_ch)
                    specs.append(ConvSpec(
                        name=node.name, c=in_ch, h=hw, w=hw, m=out_ch,
                        r=node.kernel, s=node.kernel, stride=node.stride,
                        groups=in_ch if node.depthwise else 1,
                        has_bn=node.has_bn, input_is_relu=input_is_relu,
                        output_feeds_relu=node.relu_after, batch=batch))
                    in_ch = out_ch
                    hw = -(-hw // node.stride)
                    input_is_relu = node.relu_after
                elif isinstance(node, PoolNode):
                    hw = -(-hw // node.stride)
                    input_is_relu = False
                elif isinstance(node, Branch):
                    outs = []
                    hws = []
                    for path in node.paths:
                        o, h2 = walk(path, in_ch, hw, False)
                        outs.append(o)
                        hws.append(h2)
                    in_ch = sum(outs) if node.merge == "concat" else outs[0]
                    hw = hws[0]
                    input_is_relu = node.merge == "add"
            return in_ch, hw

        walk(self.layers, self.in_ch, self.image_size, False)
        return specs

    def gemm_workload(self, batch: int) -> List[dict]:
        """Per-layer, per-stage GEMM requests this model's training step
        lowers onto the ``kernels.ops.sparse_gemm`` dispatcher: one row per
        (layer, stage ∈ {fp, bp_dx, wg}) with the per-group (M, K, N) dims
        and group count — the shardable workload description a ``GemmSpec``
        is resolved against (consumed by
        ``benchmarks/kernel_audit.launch_shape_audit``)."""
        rows: List[dict] = []
        for s in self.conv_specs(batch):
            g = s.groups
            t_out = batch * s.u * s.v            # output pixels (FP/WG rows)
            t_in = batch * s.h * s.w             # input pixels (dX rows)
            for stage, m, k, n in (
                    ("fp", t_out, s.crs, s.m // g),
                    ("bp_dx", t_in, s.mrs, s.c // g),
                    ("wg", s.crs, t_out, s.m // g)):
                # cin/cout let the consumer recompute the engine's channel
                # granularities (conv_channel_granularity needs the FULL
                # channel counts, not the per-group dims).
                rows.append({"layer": s.name, "stage": stage, "groups": g,
                             "m": m, "k": k, "n": n,
                             "cin": s.c, "cout": s.m})
        return rows


def build_cnn(name: str, *, image_size: int = 32, width: float = 1.0,
              num_classes: int = 100) -> CNNModel:
    import copy
    layers = copy.deepcopy(NETWORKS[name](width))
    return CNNModel(name=name, layers=layers, num_classes=num_classes,
                    image_size=image_size)
