"""Feed-forward blocks: GLU (silu/gelu) and plain (relu/gelu/relu²).

For ReLU-family activations the down-projection is routed through
``core.act_matmul`` — the paper's fused unit — so the backward pass gets
OUTPUT sparsity (tiles the activation mask kills are skipped) and the
up-projection's backward gets INPUT sparsity from the now-sparse hidden
gradient.  GLU activations are dense by construction (paper §2.1 scopes
them out); they use plain matmuls.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import SparsityPolicy
from repro.core.sparse_linear import act_matmul, matmul as sparse_matmul
from repro.kernels import stats
from .common import activation_fn, dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class FFNConfig:
    d_model: int
    d_ff: int
    activation: str = "silu_glu"      # silu_glu|gelu_glu|relu|gelu|relu2
    sparse_policy: Optional[SparsityPolicy] = None  # only for relu/relu2

    @property
    def is_glu(self) -> bool:
        return self.activation.endswith("_glu")

    @property
    def relu_family(self) -> bool:
        return self.activation in ("relu", "relu2")


def ffn_init(key, cfg: FFNConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if cfg.is_glu:
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
            "w_up": dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
            "w_down": dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype),
        }
    return {
        "w_up": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
        "w_down": dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype),
    }


def ffn_apply(params: Params, x: jnp.ndarray, cfg: FFNConfig) -> jnp.ndarray:
    """x: (..., d_model)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if cfg.is_glu:
        act = activation_fn(cfg.activation.split("_")[0])
        h = act(x2 @ params["w_gate"]) * (x2 @ params["w_up"])
        y = h @ params["w_down"]
    elif cfg.relu_family and cfg.sparse_policy is not None \
            and cfg.sparse_policy.any_sparsity:
        pol = cfg.sparse_policy
        # up-projection: plain sparse matmul (its bwd consumes the sparse
        # hidden gradient → INPUT sparsity), then the paper's fused unit.
        with stats.layer_scope("ffn_up"):
            h_pre = sparse_matmul(x2, params["w_up"], pol)
        with stats.layer_scope("ffn_down"):
            y = act_matmul(h_pre, params["w_down"], pol, cfg.activation)
    else:
        act = activation_fn(cfg.activation)
        y = act(x2 @ params["w_up"]) @ params["w_down"]
    return y.reshape(*shape[:-1], -1)
