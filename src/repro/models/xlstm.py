"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory), used by the xlstm-350m architecture in an alternating stack.

mLSTM: per-head matrix state C ∈ R^{Dh×Dh} with exponential input/forget
gating and max-stabilizer m; parallelizable — we scan over chunks carrying
(C, n, m) and use a decay-weighted intra-chunk attention-like form.

sLSTM: per-unit scalar state with recurrent (block-diagonal per head)
hidden feedback — inherently sequential; lax.scan over time.

Simplifications vs the reference implementation (noted in DESIGN.md):
learnable skip/gate initializations are default-valued; no causal-conv
pre-layer on the mLSTM query/key path; group-norm replaced by per-head
RMS normalization of the readout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor_m: float = 2.0      # mLSTM up-projection
    proj_factor_s: float = 4.0 / 3  # sLSTM FFN factor
    chunk: int = 64
    unroll: int = 1

    @property
    def d_inner_m(self) -> int:
        return int(self.d_model * self.proj_factor_m)

    @property
    def head_dim_m(self) -> int:
        return self.d_inner_m // self.n_heads

    @property
    def head_dim_s(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    di = cfg.d_inner_m
    return {
        "w_up": dense_init(ks[0], cfg.d_model, 2 * di, dtype),
        "w_q": dense_init(ks[1], di, di, dtype),
        "w_k": dense_init(ks[2], di, di, dtype),
        "w_v": dense_init(ks[3], di, di, dtype),
        "w_i": dense_init(ks[4], di, cfg.n_heads, jnp.float32),
        "w_f": dense_init(ks[5], di, cfg.n_heads, jnp.float32),
        "b_i": jnp.zeros((cfg.n_heads,), jnp.float32),
        "b_f": jnp.full((cfg.n_heads,), 3.0, jnp.float32),  # forget-open init
        "w_down": dense_init(ks[6], di, cfg.d_model, dtype),
    }


def _mlstm_chunk(carry, xs, head_dim):
    """Chunkwise-parallel mLSTM (decay-matrix form) for one chunk.

    carry: (C (B,H,D,D), n (B,H,D), m (B,H)); xs: q,k,v (B,ch,H,D), i,f (B,ch,H)
    """
    C0, n0, m0 = carry
    q, k, v, ig, fg = xs
    b, ch, h, d = q.shape
    logf = jax.nn.log_sigmoid(fg)                       # (B,ch,H)
    cum_f = jnp.cumsum(logf, axis=1)                    # Σ_{s<=t} log f_s
    # stabilizer: m_t = max(m_{t-1} + Σ log f, max_s(i_s + Σ_{u in (s,t]} log f))
    a_val = ig + (cum_f[:, -1:, :] - cum_f)             # intra-chunk key decay→end
    m_inter = m0 + cum_f[:, -1, :]                      # carry decay to chunk end
    m_new = jnp.maximum(m_inter, a_val.max(axis=1))     # (B,H)

    # intra-chunk pairwise decay D[t,s] = exp(cumf_t - cumf_s + i_s - m_t*)
    # with per-step stabilizer m_t* = max(m0 + cumf_t, max_{s<=t}(i_s + cumf_t - cumf_s))
    dec_ts = cum_f[:, :, None, :] - cum_f[:, None, :, :] + ig[:, None, :, :]
    causal = jnp.tril(jnp.ones((ch, ch), bool))
    dec_ts = jnp.where(causal[None, :, :, None], dec_ts, -jnp.inf)
    m_step = jnp.maximum(m0[:, None] + cum_f, dec_ts.max(axis=2))  # (B,ch,H)
    d_mat = jnp.exp(dec_ts - m_step[:, :, None, :])     # (B,ch,ch,H)
    carry_dec = jnp.exp(m0[:, None] + cum_f - m_step)   # (B,ch,H)

    qf = q.astype(jnp.float32) * (d ** -0.5)
    s_intra = jnp.einsum("bthd,bshd->btsh", qf, k.astype(jnp.float32)) * d_mat
    num = jnp.einsum("btsh,bshd->bthd", s_intra, v.astype(jnp.float32)) \
        + jnp.einsum("bthd,bhde,bth->bthe", qf, C0, carry_dec)
    den = jnp.abs(jnp.einsum("btsh,bsh->bth", s_intra, jnp.ones((b, ch, h))) * 0
                  + s_intra.sum(axis=2)
                  + jnp.einsum("bthd,bhd,bth->bth", qf, n0, carry_dec))
    hy = num / jnp.maximum(den, jnp.exp(-m_step))[..., None]

    # carry update to chunk end
    k_dec = jnp.exp(a_val - m_new[:, None])             # (B,ch,H)
    C1 = C0 * jnp.exp(m_inter - m_new)[..., None, None] \
        + jnp.einsum("bshd,bsh,bshe->bhde", k.astype(jnp.float32), k_dec,
                     v.astype(jnp.float32))
    n1 = n0 * jnp.exp(m_inter - m_new)[..., None] \
        + jnp.einsum("bshd,bsh->bhd", k.astype(jnp.float32), k_dec)
    return (C1, n1, m_new), hy


def mlstm_apply(params: Params, x: jnp.ndarray, cfg: XLSTMConfig) -> jnp.ndarray:
    b, t, _ = x.shape
    h, d = cfg.n_heads, cfg.head_dim_m
    up, gate = jnp.split(x @ params["w_up"], 2, axis=-1)
    q = (up @ params["w_q"]).reshape(b, t, h, d)
    k = (up @ params["w_k"]).reshape(b, t, h, d)
    v = (up @ params["w_v"]).reshape(b, t, h, d)
    ig = (up.astype(jnp.float32) @ params["w_i"]) + params["b_i"]
    fg = (up.astype(jnp.float32) @ params["w_f"]) + params["b_f"]

    ch = min(cfg.chunk, t)
    assert t % ch == 0, (t, ch)
    nc = t // ch
    def to_chunks(a):
        return a.reshape(b, nc, ch, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
    xs = tuple(map(to_chunks, (q, k, v, ig, fg)))
    carry = (jnp.zeros((b, h, d, d), jnp.float32),
             jnp.zeros((b, h, d), jnp.float32),
             jnp.full((b, h), -1e30, jnp.float32))
    body = jax.checkpoint(lambda c, z: _mlstm_chunk(c, z, d), prevent_cse=False)
    _, hy = jax.lax.scan(body, carry, xs, unroll=cfg.unroll)
    hy = hy.transpose(1, 0, 2, 3, 4).reshape(b, t, h * d)
    # per-head RMS readout norm + output gate
    hy = hy / jnp.maximum(jnp.sqrt(jnp.mean(hy ** 2, -1, keepdims=True)), 1e-6)
    out = (hy * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype)
    return out @ params["w_down"]


def mlstm_init_cache(cfg: XLSTMConfig, batch: int) -> Params:
    h, d = cfg.n_heads, cfg.head_dim_m
    return {
        "C": jnp.zeros((batch, h, d, d), jnp.float32),
        "n": jnp.zeros((batch, h, d), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(params: Params, x: jnp.ndarray, cache: Params,
                 cfg: XLSTMConfig) -> Tuple[jnp.ndarray, Params]:
    b = x.shape[0]
    h, d = cfg.n_heads, cfg.head_dim_m
    up, gate = jnp.split(x @ params["w_up"], 2, axis=-1)   # (B,1,di)
    q = (up @ params["w_q"]).reshape(b, h, d)
    k = (up @ params["w_k"]).reshape(b, h, d)
    v = (up @ params["w_v"]).reshape(b, h, d)
    ig = ((up.astype(jnp.float32) @ params["w_i"]) + params["b_i"])[:, 0]
    fg = ((up.astype(jnp.float32) @ params["w_f"]) + params["b_f"])[:, 0]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + cache["m"], ig)
    fd = jnp.exp(logf + cache["m"] - m_new)
    idec = jnp.exp(ig - m_new)
    C1 = cache["C"] * fd[..., None, None] + \
        jnp.einsum("bhd,bhe,bh->bhde", k.astype(jnp.float32),
                   v.astype(jnp.float32), idec)
    n1 = cache["n"] * fd[..., None] + k.astype(jnp.float32) * idec[..., None]
    qf = q.astype(jnp.float32) * (d ** -0.5)
    num = jnp.einsum("bhd,bhde->bhe", qf, C1)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n1))
    hy = (num / jnp.maximum(den, jnp.exp(-m_new))[..., None]).reshape(b, 1, h * d)
    hy = hy / jnp.maximum(jnp.sqrt(jnp.mean(hy ** 2, -1, keepdims=True)), 1e-6)
    out = (hy * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype)
    return out @ params["w_down"], {"C": C1, "n": n1, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim_s
    dff = int(d * cfg.proj_factor_s)
    def rec(k):
        return (jax.random.normal(k, (h, hd, hd), jnp.float32) * hd ** -0.5)
    return {
        "w_ifzo": dense_init(ks[0], d, 4 * d, dtype),
        "r_i": rec(ks[1]), "r_f": rec(ks[2]), "r_z": rec(ks[3]), "r_o": rec(ks[4]),
        "b_ifzo": jnp.zeros((4 * d,), jnp.float32),
        "w_ff1": dense_init(ks[5], d, dff, dtype),
        "w_ff2": dense_init(ks[6], dff, d, dtype),
    }


def _slstm_step(params, cfg: XLSTMConfig, carry, xt):
    """xt: (B, 4d) pre-computed input projections."""
    c0, n0, m0, h0 = carry                      # (B,H,hd) each, m0/n0 (B,H,hd)
    b = xt.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim_s
    hr = h0.reshape(b, h, hd)
    rec = lambda r: jnp.einsum("bhd,hde->bhe", hr, r)
    xi, xf, xz, xo = jnp.split(xt.astype(jnp.float32) + params["b_ifzo"], 4, -1)
    sh = lambda a: a.reshape(b, h, hd)
    it = sh(xi) + rec(params["r_i"])
    ft = sh(xf) + rec(params["r_f"])
    zt = jnp.tanh(sh(xz) + rec(params["r_z"]))
    ot = jax.nn.sigmoid(sh(xo) + rec(params["r_o"]))
    logf = jax.nn.log_sigmoid(ft)
    m1 = jnp.maximum(logf + m0, it)
    c1 = c0 * jnp.exp(logf + m0 - m1) + jnp.exp(it - m1) * zt
    n1 = n0 * jnp.exp(logf + m0 - m1) + jnp.exp(it - m1)
    h1 = ot * c1 / jnp.maximum(n1, 1e-6)
    return (c1, n1, m1, h1.reshape(b, h * hd)), h1.reshape(b, h * hd)


def slstm_apply(params: Params, x: jnp.ndarray, cfg: XLSTMConfig) -> jnp.ndarray:
    b, t, d = x.shape
    xt = (x @ params["w_ifzo"]).transpose(1, 0, 2)          # (T,B,4d)
    carry = slstm_init_cache(cfg, b)
    carry = (carry["c"], carry["n"], carry["m"], carry["h"])
    step = lambda c, z: _slstm_step(params, cfg, c, z)
    _, hy = jax.lax.scan(step, carry, xt)
    hy = hy.transpose(1, 0, 2).astype(x.dtype)              # (B,T,d)
    # post-FFN (proj factor 4/3, GELU)
    y = jax.nn.gelu((hy @ params["w_ff1"]).astype(jnp.float32))
    return (y.astype(x.dtype) @ params["w_ff2"])


def slstm_init_cache(cfg: XLSTMConfig, batch: int) -> Params:
    h, hd = cfg.n_heads, cfg.head_dim_s
    return {
        "c": jnp.zeros((batch, h, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h, hd), -1e30, jnp.float32),
        "h": jnp.zeros((batch, h * hd), jnp.float32),
    }


def slstm_decode(params: Params, x: jnp.ndarray, cache: Params,
                 cfg: XLSTMConfig) -> Tuple[jnp.ndarray, Params]:
    b = x.shape[0]
    xt = (x @ params["w_ifzo"])[:, 0]                        # (B,4d)
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    (c1, n1, m1, h1), hy = _slstm_step(params, cfg, carry, xt)
    hy = hy[:, None].astype(x.dtype)
    y = jax.nn.gelu((hy @ params["w_ff1"]).astype(jnp.float32))
    out = y.astype(x.dtype) @ params["w_ff2"]
    return out, {"c": c1, "n": n1, "m": m1, "h": h1}
