"""Model zoo: LM-family transformers (dense/MoE/SSM-hybrid/xLSTM/enc-dec)
and the paper's five CNNs, all pure-functional JAX."""
from . import attention, cnn, common, ffn, moe, ssm, transformer, xlstm  # noqa: F401
