"""On-device work-queue construction: stream compaction by prefix sum.

The compacted schedule (masked_matmul.compact_masked_matmul_kernel) consumes
an explicit queue of active output-tile coordinates ``(ii, jj, n_active)``.
The seed built that queue with ``jnp.argsort`` over the flattened (Mb, Nb)
tile bitmap — an O(T log T) sort sitting on the critical path of every
backward step, growing with model size.  The WDU principle (paper §4.6, and
the SparseTrain/TensorDash lesson) is that scheduling metadata must be a
near-free byproduct of the dataflow, so this kernel replaces the sort with
an exclusive-prefix-sum *stream compaction*: O(T) work, one pass.

Algorithm (classic GPU/TPU stream compaction, done blockwise):

  1. flatten the bitmap row-major (the WDU's "lexicographically smallest
     state tuple first" order is exactly row-major (i, j));
  2. walk it in launch blocks of L elements (sequential TPU grid);
  3. inside a block: exclusive prefix sum of the flags (a local cumsum);
  4. across blocks: a scalar carry in SMEM accumulates the running count,
     so element t's queue slot is ``carry + local_exclusive_scan[t]``;
  5. each live element stores its (i, j) = (t // Nb, t % Nb) at its slot.
     Dead elements — and live elements past ``capacity`` (overflow) — are
     steered to a dump slot one past the queue, so stores are unconditional
     and overflow never corrupts slots [0, capacity).

The emitted order is *identical* to the retained argsort reference (both
are row-major-stable); ``core.workredist.static_queue_order`` is the
executable statement of that contract and the property suite
(tests/test_queue_builder.py) pins all three against each other.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import stats

try:  # TPU-specific helpers; present in jax>=0.4 under .tpu
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

# Launch-block length for the flattened bitmap walk.  Bitmaps are tiny
# (tile counts, not elements), so one VPU-friendly row per grid step is
# plenty; the carry makes the block size a pure tuning knob.
DEFAULT_QUEUE_BLOCK = 256


def _queue_builder_kernel(bm_ref, ii_ref, jj_ref, cnt_ref, carry_ref,
                          *, cap: int, nj: int, lb: int):
    """Grid = (T // lb,).  Step b compacts flat elements [b*lb, (b+1)*lb)."""
    b = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        carry_ref[0] = 0
        # Dead queue slots must hold VALID coordinates: the consumer gathers
        # operand tiles at (ii[s], jj[s]) even for s >= n_active.
        ii_ref[...] = jnp.zeros_like(ii_ref)
        jj_ref[...] = jnp.zeros_like(jj_ref)

    flags = (bm_ref[...] != 0).astype(jnp.int32)[0]     # (lb,)
    excl = jnp.cumsum(flags) - flags                     # exclusive scan
    base = carry_ref[0]                                  # carry across blocks

    def _store(e, _):
        t = b * lb + e                                   # flat bitmap index
        # Live → its compacted slot; dead or overflow → the dump slot.
        slot = jnp.where(flags[e] != 0, base + excl[e], cap)
        slot = jnp.minimum(slot, cap)
        ii_ref[pl.dslice(slot, 1), :] = jnp.full((1, 1), t // nj, jnp.int32)
        jj_ref[pl.dslice(slot, 1), :] = jnp.full((1, 1), t % nj, jnp.int32)
        return 0

    jax.lax.fori_loop(0, lb, _store, 0)
    carry_ref[0] = base + jnp.sum(flags)

    @pl.when(b == nb - 1)
    def _emit_count():
        cnt_ref[0, 0] = carry_ref[0]


def build_queue_kernel(
    bitmap: jnp.ndarray,          # (Mb, Nb) int32 tile bitmap
    *,
    capacity: int,
    launch_block: int = DEFAULT_QUEUE_BLOCK,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compact ``bitmap`` into ``(ii, jj, n_live)`` — no sort anywhere.

    Returns row-major (WDU reference order) coordinates of the set bits:
    ``ii``/``jj`` are (capacity,) int32, zero-padded past the live count;
    ``n_live`` is (1,) int32 and is the TRUE number of set bits (it may
    exceed ``capacity`` — callers use that to trigger the overflow
    fallback; only the first ``min(n_live, capacity)`` slots are filled).
    """
    mb, nb = bitmap.shape
    t = mb * nb
    lb = min(launch_block, t)
    tp = (t + lb - 1) // lb * lb
    flat = bitmap.reshape(-1).astype(jnp.int32)
    if tp != t:
        flat = jnp.pad(flat, (0, tp - t))                # padding is dead
    blocks = flat.reshape(tp // lb, lb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(tp // lb,),
        in_specs=[pl.BlockSpec((1, lb), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((capacity + 1, 1), lambda b: (0, 0)),
            pl.BlockSpec((capacity + 1, 1), lambda b: (0, 0)),
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
    )
    fn = pl.pallas_call(
        functools.partial(_queue_builder_kernel, cap=capacity, nj=nb, lb=lb),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((capacity + 1, 1), jnp.int32),  # +dump slot
            jax.ShapeDtypeStruct((capacity + 1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )
    ii, jj, cnt = fn(blocks)
    return ii[:capacity, 0], jj[:capacity, 0], cnt[0]


# ---------------------------------------------------------------------------
# Builder dispatch — the queue-construction side of the sparse_gemm call
# contract.  kernels/ops.py's dispatcher calls ONLY this function; the
# argsort reference lives here next to the kernel it is the oracle for.
# ---------------------------------------------------------------------------

def _parse_version(v: str):
    """Leading-digit parse per component: '0.4.27rc1' → (0, 4, 27); any
    unparseable component compares as 0 (never an import-time crash)."""
    import re
    out = []
    for part in v.split(".")[:3]:
        m = re.match(r"\d+", part)
        out.append(int(m.group()) if m else 0)
    return tuple(out)


_JAX_VERSION = _parse_version(jax.__version__)


def _stable_argsort_desc(flat: jnp.ndarray) -> jnp.ndarray:
    """Stable descending argsort of a {0,1} vector (active indices first,
    row-major within each class) — the retained O(T log T) queue-builder
    reference.  ``stable=`` only exists from jax 0.4.27; earlier releases
    sort stably by default, so the kwarg is version-gated, not assumed."""
    if _JAX_VERSION >= (0, 4, 27):
        return jnp.argsort(-flat, stable=True)
    return jnp.argsort(-flat)  # pre-0.4.27 argsort is stable by default


def build_queue(
    bitmap: jnp.ndarray,
    *,
    capacity: int,
    builder: str = "prefix_sum",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Active-tile queue ``(ii, jj, n_live)`` from a (Mb, Nb) tile bitmap.

    Queue order is the WDU's "lexicographically smallest state tuple first"
    — row-major (i, j); ``core.workredist.static_queue_order`` is the
    reference.  ``n_live`` (1,) is the TRUE set-bit count (may exceed
    ``capacity``; slots past it are zero-padded).

    builder="prefix_sum" (default): the Pallas blockwise exclusive-prefix-
    sum stream compaction above — O(T), no sort on the critical path.
    builder="argsort": the seed's O(T log T) sort, kept as the reference
    and fallback.  Each construction is counted by ``stats`` as
    ``queue:<builder>``.
    """
    mb, nb = bitmap.shape
    stats.record(f"queue:{builder}")
    if builder == "argsort":
        with stats.lifecycle_scope("queue", builder):
            flat = bitmap.reshape(-1)
            order = _stable_argsort_desc(flat)[:capacity]
            if order.shape[0] < capacity:       # capacity may exceed T
                order = jnp.pad(order, (0, capacity - order.shape[0]))
            ii = (order // nb).astype(jnp.int32)
            jj = (order % nb).astype(jnp.int32)
            # Dead slots must carry valid (in-range) coords for the
            # consumer's gathers; zero them like the prefix-sum builder.
            live = jnp.arange(capacity) < flat.sum()
            ii = jnp.where(live, ii, 0)
            jj = jnp.where(live, jj, 0)
            return ii, jj, flat.sum().reshape(1)
    if builder != "prefix_sum":
        raise ValueError(f"unknown queue builder: {builder!r}")
    with stats.lifecycle_scope("queue", builder):
        return build_queue_kernel(bitmap, capacity=capacity,
                                  interpret=interpret)
