"""Block-sparse GEMM Pallas TPU kernels — the compute core of the paper.

The paper skips MACs at element granularity using per-neuron offset lanes
(input sparsity) and the forward-pass ReLU bitmap (output sparsity).  The
TPU-native unit of skipping is an MXU block, so both sparsity types become
*block bitmaps*:

  out_mask (Mb, Nb):  1 ⇔ the forward ReLU mask has ≥1 nonzero in this
                      output tile → the tile must be computed.  0 ⇔ the
                      Hadamard with σ'(z) would zero the whole tile → the
                      producer GEMM never computes it (OUTPUT sparsity).
  a_mask   (Mb, Kb):  1 ⇔ the incoming-gradient tile has ≥1 nonzero
                      (INPUT sparsity; the paper's TC-sparsity offsets).
  b_mask   (Kb, Nb):  same for the second operand (used by the WG stage,
                      where both activations and gradients are sparse).

Two schedules are provided:

  * *predicated* (``grouped_masked_matmul_kernel``): full (G, Mb, Nb, Kb)
    grid, each step guards its MXU issue and its accumulator write with
    ``pl.when``.  This mirrors the paper's baseline sparse PE (lanes idle
    on skipped work → load imbalance across tiles).

  * *compacted* ("work redistribution",
    ``grouped_compact_masked_matmul_kernel``): the grid walks a scalar-
    prefetched queue of ACTIVE (g, i, j) block coordinates only, so work
    per sequential grid step is uniform by construction.  This is the TPU
    analogue of the paper's WDU (§4.6): the WDU rebalances remaining work
    at runtime; here the work-queue is compacted before launch, which
    achieves the same ideal occupancy bound the WDU approaches (its ~83%
    vs the queue's 100% of active blocks).

All kernels accumulate in a f32 VMEM scratch across the K grid dimension
and are exact: a skipped output tile is exactly the zero tile the dense
computation would have produced post-Hadamard.

Since the spec-driven redesign (docs/gemm_api.md), ``kernels.ops.
sparse_gemm`` launches ONLY the grouped kernels — a 2-D GEMM is the G=1
special case.  The 2-D kernels (``masked_matmul_kernel``,
``compact_masked_matmul_kernel``) are RETAINED as the pre-redesign
reference: tests/test_gemm_spec.py pins sparse_gemm(G=1) bit-exact against
them, the same role the argsort queue builder plays for the prefix-sum one.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific helpers; present in jax>=0.4 under .tpu
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


# ---------------------------------------------------------------------------
# Predicated kernel (2-D; retained pre-redesign reference — see module doc)
# ---------------------------------------------------------------------------

def _mm_kernel(out_m_ref, a_m_ref, b_m_ref, a_ref, b_ref, o_ref, acc_ref):
    """Grid = (Mb, Nb, Kb); K innermost so ``acc_ref`` accumulates per tile."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Output sparsity: the whole (i, j) tile is dead if the ReLU bitmap says
    # so.  Input sparsity: this K-step contributes nothing if either operand
    # tile is all-zero.
    active = (
        (out_m_ref[i, j] != 0)
        & (a_m_ref[i, k] != 0)
        & (b_m_ref[k, j] != 0)
    )

    @pl.when(active)
    def _issue_mxu():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_epilogue_kernel(out_m_ref, a_m_ref, b_m_ref, a_ref, b_ref, mult_ref,
                        o_ref, acc_ref):
    """Predicated kernel + fused σ′-Hadamard epilogue: the final accumulator
    write multiplies by the (bm, bn) tile of ``mult`` — the backward pass's
    ``dx * σ'(z)`` never round-trips through HBM as a separate VPU pass."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    active = (
        (out_m_ref[i, j] != 0)
        & (a_m_ref[i, k] != 0)
        & (b_m_ref[k, j] != 0)
    )

    @pl.when(active)
    def _issue_mxu():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _write():
        o_ref[...] = (acc_ref[...] * mult_ref[...]).astype(o_ref.dtype)


def masked_matmul_kernel(
    a: jnp.ndarray,
    b: jnp.ndarray,
    out_mask: jnp.ndarray,
    a_mask: jnp.ndarray,
    b_mask: jnp.ndarray,
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=jnp.float32,
    epilogue_mult: Optional[jnp.ndarray] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw predicated kernel launch.  Shapes must be block-aligned.

    ``epilogue_mult`` (M, N) f32, if given, is Hadamard-applied to each
    output tile inside the kernel at accumulator-writeback time.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (a.shape, b.shape, bm, bk, bn)
    ni, nj, nk = m // bm, n // bn, k // bk
    assert out_mask.shape == (ni, nj), (out_mask.shape, (ni, nj))
    assert a_mask.shape == (ni, nk), (a_mask.shape, (ni, nk))
    assert b_mask.shape == (nk, nj), (b_mask.shape, (nk, nj))

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k, *_: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k, *_: (k, j)),
    ]
    operands = [a, b]
    kernel = _mm_kernel
    if epilogue_mult is not None:
        assert epilogue_mult.shape == (m, n), (epilogue_mult.shape, (m, n))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)))
        operands.append(epilogue_mult.astype(jnp.float32))
        kernel = _mm_epilogue_kernel

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(ni, nj, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )
    return fn(
        out_mask.astype(jnp.int32),
        a_mask.astype(jnp.int32),
        b_mask.astype(jnp.int32),
        *operands,
    )


# ---------------------------------------------------------------------------
# Composable epilogue stages — ONE application point per kernel family
# ---------------------------------------------------------------------------

def _apply_epilogue(acc, mult_tile, o_dtype, emit_gran):
    """The single epilogue application point, shared by both grouped kernel
    families.  Stages compose in canonical order:

      1. ``sigma_prime`` — Hadamard with the (already-gathered) multiplier
         tile (``mult_tile`` is None when the stage is off);
      2. ``bitmap_emit`` — reduce the POST-σ′ tile to its (er, ec)
         any-nonzero bitmap (``emit_gran`` is None when the stage is off),
         so the emitted bits describe exactly the values written back.

    ``acc`` may be (bm, bn) (predicated family) or (1, bm, bn) (compact
    family); the returned bits are always the 2-D (bm//er, bn//ec) tile.
    """
    out = acc if mult_tile is None else acc * mult_tile
    bits = None
    if emit_gran is not None:
        er, ec = emit_gran
        v = out if out.ndim == 2 else out[0]
        r, c = v.shape
        vb = jnp.abs(v).reshape(r // er, er, c // ec, ec)
        bits = (jnp.max(vb, axis=(1, 3)) > 0).astype(jnp.int32)
    return out.astype(o_dtype), bits


def _epilogue_refs(refs, has_mult, emit_gran):
    """Decode the trailing ref list ``[mult?] o [bits?] acc`` of a variant
    kernel: optional multiplier first, output(s) in the middle, the f32
    accumulator scratch always last."""
    mult_ref = refs[0] if has_mult else None
    o_ref = refs[1] if has_mult else refs[0]
    bits_ref = refs[-2] if emit_gran is not None else None
    return mult_ref, o_ref, bits_ref, refs[-1]


# ---------------------------------------------------------------------------
# Grouped predicated kernel — one launch covers all G independent GEMMs of a
# grouped/depthwise conv (grid gains a leading group dimension; masks carry a
# leading G axis).  Semantics per group are identical to the 2-D kernel.
# ---------------------------------------------------------------------------

def _gmm_kernel(out_m_ref, a_m_ref, b_m_ref, a_ref, b_ref, *refs,
                has_mult: bool = False,
                emit_gran: Optional[Tuple[int, int]] = None):
    """Grid = (G, Mb, Nb, Kb); K innermost so ``acc_ref`` accumulates.

    One body serves every epilogue combination — the trailing refs are
    ``[mult?] o [bits?] acc`` per ``_epilogue_refs`` and the writeback goes
    through ``_apply_epilogue`` (the only place stages are applied)."""
    mult_ref, o_ref, bits_ref, acc_ref = \
        _epilogue_refs(refs, has_mult, emit_gran)
    g = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    active = (
        (out_m_ref[g, i, j] != 0)
        & (a_m_ref[g, i, k] != 0)
        & (b_m_ref[g, k, j] != 0)
    )

    @pl.when(active)
    def _issue_mxu():
        acc_ref[...] += jnp.dot(
            a_ref[0], b_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _write():
        out, bits = _apply_epilogue(
            acc_ref[...], None if mult_ref is None else mult_ref[0],
            o_ref.dtype, emit_gran)
        o_ref[0] = out
        if bits_ref is not None:
            bits_ref[0] = bits


def gmm_kernel_variant(has_mult: bool,
                       emit_gran: Optional[Tuple[int, int]] = None):
    """The predicated family's variant selector: binds the epilogue
    configuration onto ``_gmm_kernel`` (a named closure so the sanitizer's
    ``__module__``/``__name__`` resolution keeps working)."""
    if not has_mult and emit_gran is None:
        return _gmm_kernel

    def kernel(out_m_ref, a_m_ref, b_m_ref, a_ref, b_ref, *refs):
        _gmm_kernel(out_m_ref, a_m_ref, b_m_ref, a_ref, b_ref, *refs,
                    has_mult=has_mult, emit_gran=emit_gran)

    kernel.__name__ = f"_gmm_kernel[mult={int(has_mult)},emit={emit_gran}]"
    return kernel


def grouped_masked_matmul_kernel(
    a: jnp.ndarray,          # (G, M, K) block-aligned
    b: jnp.ndarray,          # (G, K, N)
    out_mask: jnp.ndarray,   # (G, Mb, Nb) int32
    a_mask: jnp.ndarray,     # (G, Mb, Kb)
    b_mask: jnp.ndarray,     # (G, Kb, Nb)
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=jnp.float32,
    epilogue_mult: Optional[jnp.ndarray] = None,   # (G, M, N) f32
    emit_gran: Optional[Tuple[int, int]] = None,
    interpret: bool = False,
):
    """Raw grouped predicated launch: G independent masked GEMMs, one grid.

    With ``emit_gran=(er, ec)`` the launch grows a second output — the
    packed (G, M//er, N//ec) int32 any-nonzero bitmap of the written
    values, emitted at accumulator writeback — and returns ``(out, bits)``.
    """
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (a.shape, bm, bk, bn)
    ni, nj, nk = m // bm, n // bn, k // bk
    assert out_mask.shape == (g, ni, nj), (out_mask.shape, (g, ni, nj))
    assert a_mask.shape == (g, ni, nk), (a_mask.shape, (g, ni, nk))
    assert b_mask.shape == (g, nk, nj), (b_mask.shape, (g, nk, nj))

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, *_: (gi, i, k)),
        pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, *_: (gi, k, j)),
    ]
    operands = [a, b]
    if epilogue_mult is not None:
        assert epilogue_mult.shape == (g, m, n), epilogue_mult.shape
        in_specs.append(
            pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, *_: (gi, i, j)))
        operands.append(epilogue_mult.astype(jnp.float32))
    kernel = gmm_kernel_variant(epilogue_mult is not None, emit_gran)

    out_specs = pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, *_: (gi, i, j))
    out_shape = jax.ShapeDtypeStruct((g, m, n), out_dtype)
    if emit_gran is not None:
        er, ec = emit_gran
        assert bm % er == 0 and bn % ec == 0, (emit_gran, bm, bn)
        out_specs = [out_specs, pl.BlockSpec(
            (1, bm // er, bn // ec), lambda gi, i, j, k, *_: (gi, i, j))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((g, m // er, n // ec), jnp.int32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(g, ni, nj, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    res = fn(
        out_mask.astype(jnp.int32),
        a_mask.astype(jnp.int32),
        b_mask.astype(jnp.int32),
        *operands,
    )
    if emit_gran is not None:
        return res[0], res[1]
    return res


# ---------------------------------------------------------------------------
# Grouped compacted kernel — ONE queue spans all groups: slots carry (g, i, j)
# triples in lexicographic order, so the work-redistribution schedule stays a
# single uniform stream even when every group contributes only a few tiles
# (the depthwise regime).
# ---------------------------------------------------------------------------

def _gmm_compact_kernel(
    gg_ref, ii_ref, jj_ref, n_act_ref, a_m_ref, b_m_ref, a_ref, b_ref,
    *refs, has_mult: bool = False,
    emit_gran: Optional[Tuple[int, int]] = None
):
    """Grid = (S, Kb).  Step s processes active tile (gg[s], ii[s], jj[s]).

    One body serves every epilogue combination — the trailing refs are
    ``[mult?] o [bits?] acc`` per ``_epilogue_refs`` and the writeback goes
    through ``_apply_epilogue``.  With emission on, each queue slot writes
    its own (1, bm//er, bn//ec) bits tile; dead slots write zeros (their
    accumulator never left zero), so the caller's scatter stays exact."""
    mult_ref, o_ref, bits_ref, acc_ref = \
        _epilogue_refs(refs, has_mult, emit_gran)
    s = pl.program_id(0)
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = gg_ref[s]
    i = ii_ref[s]
    j = jj_ref[s]
    live = s < n_act_ref[0]
    active = live & (a_m_ref[g, i, k] != 0) & (b_m_ref[g, k, j] != 0)

    @pl.when(active)
    def _issue_mxu():
        acc_ref[...] += jnp.dot(
            a_ref[0], b_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _write():
        out, bits = _apply_epilogue(
            acc_ref[...], None if mult_ref is None else mult_ref[0],
            o_ref.dtype, emit_gran)
        o_ref[...] = out
        if bits_ref is not None:
            bits_ref[...] = bits[None]


def gmm_compact_kernel_variant(has_mult: bool,
                               emit_gran: Optional[Tuple[int, int]] = None):
    """The compact family's variant selector (see ``gmm_kernel_variant``)."""
    if not has_mult and emit_gran is None:
        return _gmm_compact_kernel

    def kernel(gg_ref, ii_ref, jj_ref, n_act_ref, a_m_ref, b_m_ref,
               a_ref, b_ref, *refs):
        _gmm_compact_kernel(gg_ref, ii_ref, jj_ref, n_act_ref, a_m_ref,
                            b_m_ref, a_ref, b_ref, *refs,
                            has_mult=has_mult, emit_gran=emit_gran)

    kernel.__name__ = \
        f"_gmm_compact_kernel[mult={int(has_mult)},emit={emit_gran}]"
    return kernel


def grouped_compact_masked_matmul_kernel(
    a: jnp.ndarray,           # (G, M, K)
    b: jnp.ndarray,           # (G, K, N)
    gg: jnp.ndarray,          # (S,) int32 — active tile group coords
    ii: jnp.ndarray,          # (S,) int32
    jj: jnp.ndarray,          # (S,) int32
    n_active: jnp.ndarray,    # (1,) int32
    a_mask: jnp.ndarray,      # (G, Mb, Kb)
    b_mask: jnp.ndarray,      # (G, Kb, Nb)
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=jnp.float32,
    epilogue_mult: Optional[jnp.ndarray] = None,
    emit_gran: Optional[Tuple[int, int]] = None,
    interpret: bool = False,
):
    """Returns the COMPACTED output (S, bm, bn); caller scatters to (G, M, N).

    With ``emit_gran=(er, ec)`` also returns the compacted
    (S, bm//er, bn//ec) int32 bits per queue slot — scattered back by the
    caller with the same steered coordinates as the output tiles.
    """
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2
    nk = k // bk
    (s_cap,) = ii.shape
    assert gg.shape == (s_cap,) and jj.shape == (s_cap,)

    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda s, k, gg, ii, jj, *_: (gg[s], ii[s], k)),
        pl.BlockSpec((1, bk, bn), lambda s, k, gg, ii, jj, *_: (gg[s], k, jj[s])),
    ]
    operands = [a, b]
    if epilogue_mult is not None:
        assert epilogue_mult.shape == (g, m, n), epilogue_mult.shape
        in_specs.append(pl.BlockSpec(
            (1, bm, bn), lambda s, k, gg, ii, jj, *_: (gg[s], ii[s], jj[s])))
        operands.append(epilogue_mult.astype(jnp.float32))
    kernel = gmm_compact_kernel_variant(epilogue_mult is not None, emit_gran)

    out_specs = pl.BlockSpec((1, bm, bn), lambda s, k, *_: (s, 0, 0))
    out_shape = jax.ShapeDtypeStruct((s_cap, bm, bn), out_dtype)
    if emit_gran is not None:
        er, ec = emit_gran
        assert bm % er == 0 and bn % ec == 0, (emit_gran, bm, bn)
        out_specs = [out_specs, pl.BlockSpec(
            (1, bm // er, bn // ec), lambda s, k, *_: (s, 0, 0))]
        out_shape = [out_shape, jax.ShapeDtypeStruct(
            (s_cap, bm // er, bn // ec), jnp.int32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(s_cap, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((1, bm, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    res = fn(
        gg.astype(jnp.int32),
        ii.astype(jnp.int32),
        jj.astype(jnp.int32),
        n_active.astype(jnp.int32),
        a_mask.astype(jnp.int32),
        b_mask.astype(jnp.int32),
        *operands,
    )
    if emit_gran is not None:
        return res[0], res[1]
    return res


# ---------------------------------------------------------------------------
# Compacted (work-redistribution) kernel (2-D; retained pre-redesign
# reference — see module doc)
# ---------------------------------------------------------------------------

def _mm_compact_kernel(
    ii_ref, jj_ref, n_act_ref, a_m_ref, b_m_ref, a_ref, b_ref, o_ref, acc_ref
):
    """Grid = (S, Kb).  Step s processes active tile (ii[s], jj[s])."""
    s = pl.program_id(0)
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = ii_ref[s]
    j = jj_ref[s]
    live = s < n_act_ref[0]
    active = live & (a_m_ref[i, k] != 0) & (b_m_ref[k, j] != 0)

    @pl.when(active)
    def _issue_mxu():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _write():
        # Padding steps (s >= n_active) emit a zero tile; the wrapper
        # scatter-adds, so those land harmlessly on tile (0, 0).
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_compact_epilogue_kernel(
    ii_ref, jj_ref, n_act_ref, a_m_ref, b_m_ref, a_ref, b_ref, mult_ref,
    o_ref, acc_ref
):
    """Compacted schedule + fused σ′-Hadamard epilogue (mult tile gathered
    at the active coordinate (ii[s], jj[s]) via scalar prefetch)."""
    s = pl.program_id(0)
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = ii_ref[s]
    j = jj_ref[s]
    live = s < n_act_ref[0]
    active = live & (a_m_ref[i, k] != 0) & (b_m_ref[k, j] != 0)

    @pl.when(active)
    def _issue_mxu():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _write():
        o_ref[...] = (acc_ref[...] * mult_ref[...]).astype(o_ref.dtype)


def compact_masked_matmul_kernel(
    a: jnp.ndarray,
    b: jnp.ndarray,
    ii: jnp.ndarray,          # (S,) int32 — active tile row coords (0-padded)
    jj: jnp.ndarray,          # (S,) int32 — active tile col coords (0-padded)
    n_active: jnp.ndarray,    # (1,) int32 — number of live entries in ii/jj
    a_mask: jnp.ndarray,
    b_mask: jnp.ndarray,
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=jnp.float32,
    epilogue_mult: Optional[jnp.ndarray] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns the COMPACTED output (S, bm, bn); caller scatters to (M, N).

    The compacted layout is the explicit "work queue" of the paper's WDU:
    each sequential grid step carries exactly one active tile's worth of
    work, so there is no inter-tile idle time to redistribute.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    ni, nj, nk = m // bm, n // bn, k // bk
    (s_cap,) = ii.shape
    assert jj.shape == (s_cap,)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda s, k, ii, jj, *_: (ii[s], k)),
        pl.BlockSpec((bk, bn), lambda s, k, ii, jj, *_: (k, jj[s])),
    ]
    operands = [a, b]
    kernel = _mm_compact_kernel
    if epilogue_mult is not None:
        assert epilogue_mult.shape == (m, n), (epilogue_mult.shape, (m, n))
        in_specs.append(
            pl.BlockSpec((bm, bn), lambda s, k, ii, jj, *_: (ii[s], jj[s])))
        operands.append(epilogue_mult.astype(jnp.float32))
        kernel = _mm_compact_epilogue_kernel

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(s_cap, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, k, *_: (s, 0, 0)),
        scratch_shapes=[pltpu.VMEM((1, bm, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_cap, bm, bn), out_dtype),
        interpret=interpret,
    )
    return fn(
        ii.astype(jnp.int32),
        jj.astype(jnp.int32),
        n_active.astype(jnp.int32),
        a_mask.astype(jnp.int32),
        b_mask.astype(jnp.int32),
        *operands,
    )
