"""Spec-keyed GEMM autotuner — schedule and tile selection from MEASURED
live-tile stats, not static policy (ROADMAP "Spec-keyed autotuner +
wall-clock truth").

SparseTrain's adaptive-dataflow result (arXiv 2007.13595) says the best
schedule is sparsity-dependent, and sparsity drifts during training; the
static ``kernel_impl``/``work_redistribution`` resolution in
``SparsityPolicy.gemm_spec`` cannot follow that drift.  This module adds
the measured path:

  * ``AutotuneKey`` — the cache key: the spec MINUS its schedule (block,
    groups, epilogue, queue builder, out dtype) plus the block-padded
    per-group (M, K, N) when the caller's dims are known.  ``GemmSpec`` is
    frozen and hashable precisely so this key is well-defined.
  * ``AutotuneCache`` — per-key decisions ∈ {predicated, compact, dense}
    (+ a granularity-safe block refinement) from the trailing window of
    live-tile fractions that ``kernels/stats.py`` records for every
    concrete ``sparse_gemm`` dispatch.  A cached decision is re-evaluated
    when the measured out-live fraction drifts past ``drift_threshold``
    from the fraction it was decided at.  Every resolve event (default /
    measured / retune / hit) is appended to a decision log — the audit
    table ``benchmarks/kernel_audit.autotune_audit`` and the wall-clock
    harness's ``BENCH_*.json`` both render it, so every selection is
    traceable.

Resolution happens INSIDE ``SparsityPolicy.gemm_spec`` (the one sanctioned
policy→spec point) when the policy sets ``autotune=True`` — no call site
changes, and the resolved spec keeps ``origin="policy"`` so the static
analyzer's SPEC_UNRESOLVED check stays green.

Decision rule (measured out-live fraction o, operand-live fraction p, over
≥ ``min_samples`` recent dispatches):

  o ≤ compact_max_live      → "compact"    (enough dead output tiles that
                                            queue compaction pays for its
                                            construction)
  min(o, p) ≥ dense_min_live → "dense"     (nothing to skip anywhere: drop
                                            the masking machinery, let the
                                            MXU run dense)
  otherwise                  → "predicated" (moderate sparsity: per-tile
                                            guards without queue overhead)

Block refinement: when output tiles are mostly live (o ≥
``refine_block_live``) but the schedule still masks, the tile edges are
halved (floored to the caller's mask-granularity multiples) so finer tiles
can capture zeros the coarse tiles straddle.  Refinement only applies when
the caller passed ``dims`` — exactly the call sites (the grouped conv
engine) that derive their masks from the RESOLVED ``spec.block``; the
no-dims linear funnel builds masks at the policy's nominal block, so its
block is never moved.

Timing semantics: resolution runs at Python trace time.  Eager dispatches
(the wall-clock harness, probe steps) see retunes immediately; a jitted
step keeps the schedule it was traced with until it is re-traced — the
cache is host state, deliberately outside the jaxpr.  See
docs/benchmarking.md.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple, TYPE_CHECKING

from . import stats
from .shapes import ceil_to

if TYPE_CHECKING:  # avoid the ops → autotune → ops import cycle
    from .ops import GemmSpec


# ---------------------------------------------------------------------------
# The cache key: (spec minus schedule, padded shape)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutotuneKey:
    """Everything that identifies a GEMM *request* except how to run it.

    ``padded`` is the block-padded per-group (M, K, N) — the launch shape
    the decision is for — or None when the resolution point does not know
    dims (the linear-path ``gemm_spec(groups=...)`` calls); shapeless keys
    aggregate over every shape that spec serves.

    ``epilogue`` and ``out_dtype`` are deliberately NOT part of the key:
    ``core.sparse_linear._mm`` sets both on the spec AFTER policy
    resolution (``spec.with_``), so keying on them would split the
    observation stream from the resolution stream — and neither changes
    the sparsity signature the decision rule reads."""
    block: Tuple[int, int, int]
    groups: int
    queue_builder: str
    padded: Optional[Tuple[int, int, int]] = None

    @property
    def stats_key(self) -> str:
        """The ``kernels.stats`` ring-buffer key this request's live-tile
        observations are recorded under."""
        shape = "x".join(map(str, self.padded)) if self.padded else "any"
        return ("autotune:" + "x".join(map(str, self.block))
                + f":g{self.groups}:{self.queue_builder}:{shape}")


def key_for(spec: "GemmSpec",
            dims: Optional[Tuple[int, int, int]] = None) -> AutotuneKey:
    """Build the cache key from a spec (its schedule — and the
    post-resolution ``epilogue``/``out_dtype`` fields — are ignored) and
    the per-group GEMM dims, padded to the spec's block."""
    padded = None
    if dims is not None:
        padded = tuple(ceil_to(d, b) for d, b in zip(dims, spec.block))
    return AutotuneKey(
        block=tuple(spec.block), groups=spec.groups,
        queue_builder=spec.queue_builder, padded=padded)


# ---------------------------------------------------------------------------
# Decisions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Decision:
    """One cached selection, plus the measurement it was made from."""
    key: AutotuneKey
    schedule: str
    block: Tuple[int, int, int]
    live_frac: Optional[float]      # mean out-live fraction at decision time
    operand_frac: Optional[float]
    samples: int                    # measured samples behind the decision
    event: str                      # "default" | "measured" | "retune"
                                    # | "demote:<reason>"
    seq: int


# The degradation ladder (docs/resilience.md): "compact" is the most
# machinery-heavy schedule (queue construction every dispatch), "dense" the
# safest.  A quarantined key is clamped to at most the ladder rung its
# demotion level allows — a spec that persistently overflows its queue or
# trips the guard's consistency probes stops paying for the compact path.
DEGRADE_LADDER = ("compact", "predicated", "dense")


def clamp_schedule(schedule: str, level: int) -> str:
    """The schedule actually allowed for a key at demotion ``level``:
    level 0 = anything, 1 = no compact, 2 = dense only."""
    if level <= 0 or schedule not in DEGRADE_LADDER:
        return schedule
    idx = DEGRADE_LADDER.index(schedule)
    return DEGRADE_LADDER[max(idx, min(level, len(DEGRADE_LADDER) - 1))]


def _refined_block(block: Tuple[int, int, int],
                   grans: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Halve each tile edge, floored to its mask-granularity multiple —
    the only block move that keeps caller-derived masks well-formed."""
    out = []
    for b, g in zip(block, grans):
        e = max(1, b // 2)
        out.append(max(g, ceil_to(e, g)))
    return tuple(out)


class AutotuneCache:
    """Per-(spec-minus-schedule, padded shape) schedule/tile decisions from
    measured live-tile stats, with drift re-evaluation and a full decision
    log (the traceability contract)."""

    def __init__(self, *, window: int = 32, min_samples: int = 4,
                 drift_threshold: float = 0.15,
                 compact_max_live: float = 0.5,
                 dense_min_live: float = 0.98,
                 refine_block_live: float = 0.75,
                 overflow_demote_after: int = 8):
        self.window = window
        self.min_samples = min_samples
        self.drift_threshold = drift_threshold
        self.compact_max_live = compact_max_live
        self.dense_min_live = dense_min_live
        self.refine_block_live = refine_block_live
        self.overflow_demote_after = overflow_demote_after
        self.hits = 0
        self.misses = 0
        self.retunes = 0
        self.demotions = 0
        self.log: List[dict] = []
        self._decisions: Dict[AutotuneKey, Decision] = {}
        # key → demotion level on DEGRADE_LADDER (0 = unquarantined) and
        # key → {reason: count} suspicion tallies feeding the guard's
        # degrade verdict (runtime/guards.py).
        self._quarantine: Dict[AutotuneKey, int] = {}
        self._suspects: Dict[AutotuneKey, Dict[str, int]] = {}
        # dispatch signature of a resolved spec → the key that resolved it,
        # so the dispatcher's observation lands in the same buffer the NEXT
        # resolve reads even when the tuned block differs from the key's
        # nominal request.  The signature ignores schedule/epilogue/
        # out_dtype (callers may ``with_`` those after resolution).
        self._spec_keys: Dict[Any, AutotuneKey] = {}
        self._seq = itertools.count()

    @staticmethod
    def _dispatch_sig(spec: "GemmSpec",
                      dims: Optional[Tuple[int, int, int]]) -> tuple:
        padded = None if dims is None else tuple(
            ceil_to(d, b) for d, b in zip(dims, spec.block))
        return (tuple(spec.block), spec.groups, spec.queue_builder, padded)

    # -- observation ----------------------------------------------------

    def observe(self, key: AutotuneKey, out_frac: float,
                operand_frac: float = 1.0) -> None:
        """Record one measured live-tile sample for ``key`` — and for its
        shapeless twin, so no-dims resolutions see shaped traffic too."""
        stats.record_live_tiles(key.stats_key, out_frac, operand_frac)
        if key.padded is not None:
            shapeless = dataclasses.replace(key, padded=None)
            stats.record_live_tiles(shapeless.stats_key, out_frac,
                                    operand_frac)

    def _attributed_key(self, spec: "GemmSpec",
                        dims: Optional[Tuple[int, int, int]]) -> AutotuneKey:
        """The key that resolved ``spec`` (via the dispatch-signature
        reverse map), falling back to a fresh key for specs this cache
        never saw."""
        return self._spec_keys.get(self._dispatch_sig(spec, dims)) \
            or self._spec_keys.get(self._dispatch_sig(spec, None)) \
            or key_for(spec, dims)

    def observe_dispatch(self, spec: "GemmSpec",
                         dims: Tuple[int, int, int], out_frac: float,
                         operand_frac: float = 1.0) -> None:
        """Dispatcher-side entry: attribute a concrete ``sparse_gemm``'s
        measured fractions to the key that resolved ``spec``."""
        self.observe(self._attributed_key(spec, dims), out_frac,
                     operand_frac)

    # -- quarantine: the degradation ladder -----------------------------

    def quarantine_level(self, key: AutotuneKey) -> int:
        """Demotion level for ``key`` — the max of its shaped entry and
        its shapeless twin (a demotion of the spec demotes every shape)."""
        lvl = self._quarantine.get(key, 0)
        if key.padded is not None:
            twin = dataclasses.replace(key, padded=None)
            lvl = max(lvl, self._quarantine.get(twin, 0))
        return lvl

    def report_suspect(self, spec: "GemmSpec",
                       dims: Optional[Tuple[int, int, int]],
                       reason: str) -> AutotuneKey:
        """Tally one piece of evidence against the key that resolved
        ``spec`` (overflow fallback, bitmap-consistency mismatch, kernel-
        sanitizer trip).  The guard's *degrade* verdict demotes the accrued
        suspects; overflow additionally auto-demotes past its threshold."""
        key = self._attributed_key(spec, dims)
        tally = self._suspects.setdefault(key, {})
        tally[reason] = tally.get(reason, 0) + 1
        if reason == "overflow" \
                and tally[reason] >= self.overflow_demote_after \
                and self.quarantine_level(key) < 1:
            self.demote(key, reason="overflow")
        return key

    def suspects(self) -> Dict[AutotuneKey, Dict[str, int]]:
        return {k: dict(v) for k, v in self._suspects.items()}

    def demote(self, key: AutotuneKey, *, reason: str) -> Optional[str]:
        """Push ``key`` one rung down the degradation ladder.  Returns the
        newly-allowed schedule, or None when already at the bottom.  The
        demotion is a first-class decision-log event (``demote:<reason>``)
        so the audit trail shows WHY a spec left the compact schedule."""
        lvl = self._quarantine.get(key, 0)
        if lvl >= len(DEGRADE_LADDER) - 1:
            return None
        lvl += 1
        self._quarantine[key] = lvl
        self.demotions += 1
        stats.record("guard:demote")
        allowed = DEGRADE_LADDER[lvl]
        prev = self._decisions.get(key)
        block = prev.block if prev is not None else key.block
        if prev is not None:
            # Re-clamp the cached decision so subsequent hits replay (and
            # log) the demoted schedule, not the quarantined one.
            prev.schedule = clamp_schedule(prev.schedule, lvl)
        out_frac, op_frac, n = self.measured(key)
        self._append_log(
            Decision(key, allowed, tuple(block), out_frac, op_frac, n,
                     f"demote:{reason}", next(self._seq)),
            f"demote:{reason}")
        return allowed

    def demote_suspects(self, *, reason: str = "guard"
                        ) -> List[AutotuneKey]:
        """The degrade verdict's action: demote every key with accrued
        suspicion one rung; clears the tallies it acted on."""
        demoted = []
        for key in list(self._suspects):
            if self.demote(key, reason=reason) is not None:
                demoted.append(key)
            self._suspects.pop(key, None)
        return demoted

    # -- resolution -----------------------------------------------------

    def measured(self, key: AutotuneKey
                 ) -> Tuple[Optional[float], Optional[float], int]:
        return stats.live_tile_stats(key.stats_key, window=self.window)

    def resolve(self, key: AutotuneKey, default_spec: "GemmSpec", *,
                grans: Tuple[int, int, int] = (1, 1, 1),
                dims: Optional[Tuple[int, int, int]] = None) -> "GemmSpec":
        """The cache lookup: return ``default_spec`` retargeted onto the
        cached (or freshly decided) schedule/block for ``key``."""
        out_frac, op_frac, n = self.measured(key)
        prev = self._decisions.get(key)
        if prev is not None:
            newly_measured = prev.event == "default" \
                and n >= self.min_samples
            drifted = (prev.live_frac is not None and out_frac is not None
                       and abs(out_frac - prev.live_frac)
                       > self.drift_threshold)
            if not (newly_measured or drifted):
                self.hits += 1
                self._append_log(prev, "hit")
                return self._apply(prev, default_spec, key, dims)
            self.retunes += 1
            decision = self._decide(key, default_spec, out_frac, op_frac, n,
                                    grans, dims, event="retune")
        else:
            self.misses += 1
            event = "measured" if n >= self.min_samples else "default"
            decision = self._decide(key, default_spec, out_frac, op_frac, n,
                                    grans, dims, event=event)
        self._decisions[key] = decision
        self._append_log(decision, decision.event)
        return self._apply(decision, default_spec, key, dims)

    def _decide(self, key, default_spec, out_frac, op_frac, n, grans, dims,
                *, event: str) -> Decision:
        lvl = self.quarantine_level(key)
        if n < self.min_samples or out_frac is None:
            # Not enough measurement yet: the static policy resolution
            # stands, recorded as an explicit (traceable) default.
            return Decision(key,
                            clamp_schedule(default_spec.schedule, lvl),
                            tuple(default_spec.block), out_frac, op_frac, n,
                            "default", next(self._seq))
        if out_frac <= self.compact_max_live:
            schedule = "compact"
        elif min(out_frac, op_frac if op_frac is not None else 1.0) \
                >= self.dense_min_live:
            schedule = "dense"
        else:
            schedule = "predicated"
        schedule = clamp_schedule(schedule, lvl)
        block = tuple(default_spec.block)
        if schedule != "dense" and dims is not None \
                and out_frac >= self.refine_block_live:
            block = _refined_block(block, grans)
        return Decision(key, schedule, block, out_frac, op_frac, n, event,
                        next(self._seq))

    def _apply(self, decision: Decision, default_spec: "GemmSpec",
               key: AutotuneKey,
               dims: Optional[Tuple[int, int, int]]) -> "GemmSpec":
        # Defensive re-clamp: a demotion may postdate the cached decision.
        schedule = clamp_schedule(decision.schedule,
                                  self.quarantine_level(key))
        spec = default_spec.with_(schedule=schedule, block=decision.block)
        self._spec_keys[self._dispatch_sig(spec, dims)] = key
        return spec

    def _append_log(self, decision: Decision, event: str) -> None:
        self.log.append({
            "seq": decision.seq,
            "event": event,
            "key": decision.key.stats_key,
            "shape": "x".join(map(str, decision.key.padded))
            if decision.key.padded else "any",
            "groups": decision.key.groups,
            "schedule": decision.schedule,
            "block": "x".join(map(str, decision.block)),
            "live_frac": None if decision.live_frac is None
            else round(decision.live_frac, 4),
            "operand_frac": None if decision.operand_frac is None
            else round(decision.operand_frac, 4),
            "samples": decision.samples,
        })

    def decisions(self) -> Dict[AutotuneKey, Decision]:
        return dict(self._decisions)

    # -- persistence (checkpoint state.json) ----------------------------

    def export_state(self, *, log_tail: int = 256) -> dict:
        """JSON-able snapshot of the cache: decisions, quarantine levels,
        suspect tallies, counters and the decision-log tail — what a
        crash-safe resume needs so schedules don't cold-start
        (checkpoint/checkpoint.py ``state.json``)."""
        return {
            "decisions": [
                {"key": _key_doc(d.key), "schedule": d.schedule,
                 "block": list(d.block), "live_frac": d.live_frac,
                 "operand_frac": d.operand_frac, "samples": d.samples,
                 "event": d.event, "seq": d.seq}
                for d in self._decisions.values()],
            "quarantine": [
                {"key": _key_doc(k), "level": lvl}
                for k, lvl in self._quarantine.items()],
            "suspects": [
                {"key": _key_doc(k), "tally": dict(t)}
                for k, t in self._suspects.items()],
            "counters": {"hits": self.hits, "misses": self.misses,
                         "retunes": self.retunes,
                         "demotions": self.demotions},
            "log": self.log[-log_tail:],
        }

    def import_state(self, doc: dict) -> None:
        """Rehydrate a snapshot produced by ``export_state`` — resumed
        training re-enters with warm schedules and an intact quarantine."""
        max_seq = -1
        for d in doc.get("decisions", []):
            key = _key_from_doc(d["key"])
            dec = Decision(key, d["schedule"], tuple(d["block"]),
                           d["live_frac"], d["operand_frac"], d["samples"],
                           d["event"], d["seq"])
            self._decisions[key] = dec
            max_seq = max(max_seq, d["seq"])
        for q in doc.get("quarantine", []):
            key = _key_from_doc(q["key"])
            self._quarantine[key] = max(self._quarantine.get(key, 0),
                                        int(q["level"]))
        for s in doc.get("suspects", []):
            key = _key_from_doc(s["key"])
            tally = self._suspects.setdefault(key, {})
            for reason, n in s["tally"].items():
                tally[reason] = tally.get(reason, 0) + int(n)
        c = doc.get("counters", {})
        self.hits += c.get("hits", 0)
        self.misses += c.get("misses", 0)
        self.retunes += c.get("retunes", 0)
        self.demotions += c.get("demotions", 0)
        for row in doc.get("log", []):
            max_seq = max(max_seq, row.get("seq", -1))
        self.log.extend(doc.get("log", []))
        self._seq = itertools.count(max_seq + 1)


def _key_doc(key: AutotuneKey) -> dict:
    return {"block": list(key.block), "groups": key.groups,
            "queue_builder": key.queue_builder,
            "padded": None if key.padded is None else list(key.padded)}


def _key_from_doc(d: dict) -> AutotuneKey:
    return AutotuneKey(
        block=tuple(d["block"]), groups=int(d["groups"]),
        queue_builder=d["queue_builder"],
        padded=None if d["padded"] is None else tuple(d["padded"]))


# ---------------------------------------------------------------------------
# The process-global cache (mirrors the stats counters' lifetime)
# ---------------------------------------------------------------------------

_CACHE = AutotuneCache()


def get_cache() -> AutotuneCache:
    return _CACHE


def reset(**cache_kwargs) -> AutotuneCache:
    """Fresh global cache (optionally with non-default thresholds); the
    live-tile buffers in ``kernels.stats`` are cleared separately by
    ``stats.reset()``."""
    global _CACHE
    _CACHE = AutotuneCache(**cache_kwargs)
    return _CACHE


def resolve(default_spec: "GemmSpec", *,
            dims: Optional[Tuple[int, int, int]] = None,
            grans: Tuple[int, int, int] = (1, 1, 1)) -> "GemmSpec":
    """Module-level resolution entry used by ``SparsityPolicy.gemm_spec``."""
    key = key_for(default_spec, dims)
    return _CACHE.resolve(key, default_spec, grans=grans, dims=dims)


def observe_dispatch(spec: "GemmSpec", dims: Tuple[int, int, int],
                     out_frac: float, operand_frac: float = 1.0) -> None:
    """Dispatcher hook (``kernels.ops.sparse_gemm``)."""
    _CACHE.observe_dispatch(spec, dims, out_frac, operand_frac)


def report_overflow(spec: "GemmSpec",
                    dims: Optional[Tuple[int, int, int]] = None) -> None:
    """Dispatcher hook: one concrete compact dispatch overflowed its queue
    and fell back to the predicated schedule.  Past
    ``overflow_demote_after`` occurrences the key is auto-demoted off the
    compact schedule (a persistently-overflowing spec must stop paying for
    queue construction)."""
    _CACHE.report_suspect(spec, dims, "overflow")


def apply_quarantine(spec: "GemmSpec", *,
                     dims: Optional[Tuple[int, int, int]] = None
                     ) -> "GemmSpec":
    """Clamp a statically-resolved spec to its key's quarantine level —
    the non-autotune resolution path's view of the degradation ladder
    (``SparsityPolicy.gemm_spec`` calls this when ``autotune=False`` so a
    demoted spec stays demoted regardless of how it was resolved)."""
    key = key_for(spec, dims)
    lvl = _CACHE.quarantine_level(key)
    clamped = clamp_schedule(spec.schedule, lvl)
    if clamped != spec.schedule:
        stats.record("guard:quarantine_clamp")
        spec = spec.with_(schedule=clamped)
        # Keep dispatch→key attribution intact for the clamped spec so
        # subsequent observations and overflow reports land on this key.
        _CACHE._spec_keys[_CACHE._dispatch_sig(spec, dims)] = key
    return spec


def export_state() -> dict:
    """Snapshot of the global cache for checkpoint persistence."""
    return _CACHE.export_state()


def import_state(doc: dict) -> None:
    """Rehydrate the global cache from a checkpoint ``state.json``."""
    _CACHE.import_state(doc)


def log_rows() -> List[dict]:
    """The decision log — one row per resolve event, audit-table ready."""
    return list(_CACHE.log)
