"""Spec-keyed GEMM autotuner — schedule and tile selection from MEASURED
live-tile stats, not static policy (ROADMAP "Spec-keyed autotuner +
wall-clock truth").

SparseTrain's adaptive-dataflow result (arXiv 2007.13595) says the best
schedule is sparsity-dependent, and sparsity drifts during training; the
static ``kernel_impl``/``work_redistribution`` resolution in
``SparsityPolicy.gemm_spec`` cannot follow that drift.  This module adds
the measured path:

  * ``AutotuneKey`` — the cache key: the spec MINUS its schedule (block,
    groups, epilogue, queue builder, out dtype) plus the block-padded
    per-group (M, K, N) when the caller's dims are known.  ``GemmSpec`` is
    frozen and hashable precisely so this key is well-defined.
  * ``AutotuneCache`` — per-key decisions ∈ {predicated, compact, dense}
    (+ a granularity-safe block refinement) from the trailing window of
    live-tile fractions that ``kernels/stats.py`` records for every
    concrete ``sparse_gemm`` dispatch.  A cached decision is re-evaluated
    when the measured out-live fraction drifts past ``drift_threshold``
    from the fraction it was decided at.  Every resolve event (default /
    measured / retune / hit) is appended to a decision log — the audit
    table ``benchmarks/kernel_audit.autotune_audit`` and the wall-clock
    harness's ``BENCH_*.json`` both render it, so every selection is
    traceable.

Resolution happens INSIDE ``SparsityPolicy.gemm_spec`` (the one sanctioned
policy→spec point) when the policy sets ``autotune=True`` — no call site
changes, and the resolved spec keeps ``origin="policy"`` so the static
analyzer's SPEC_UNRESOLVED check stays green.

Decision rule (measured out-live fraction o, operand-live fraction p, over
≥ ``min_samples`` recent dispatches):

  o ≤ compact_max_live      → "compact"    (enough dead output tiles that
                                            queue compaction pays for its
                                            construction)
  min(o, p) ≥ dense_min_live → "dense"     (nothing to skip anywhere: drop
                                            the masking machinery, let the
                                            MXU run dense)
  otherwise                  → "predicated" (moderate sparsity: per-tile
                                            guards without queue overhead)

Block refinement: when output tiles are mostly live (o ≥
``refine_block_live``) but the schedule still masks, the tile edges are
halved (floored to the caller's mask-granularity multiples) so finer tiles
can capture zeros the coarse tiles straddle.  Refinement only applies when
the caller passed ``dims`` — exactly the call sites (the grouped conv
engine) that derive their masks from the RESOLVED ``spec.block``; the
no-dims linear funnel builds masks at the policy's nominal block, so its
block is never moved.

Timing semantics: resolution runs at Python trace time.  Eager dispatches
(the wall-clock harness, probe steps) see retunes immediately; a jitted
step keeps the schedule it was traced with until it is re-traced — the
cache is host state, deliberately outside the jaxpr.  See
docs/benchmarking.md.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple, TYPE_CHECKING

from . import stats
from .shapes import ceil_to

if TYPE_CHECKING:  # avoid the ops → autotune → ops import cycle
    from .ops import GemmSpec


# ---------------------------------------------------------------------------
# The cache key: (spec minus schedule, padded shape)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutotuneKey:
    """Everything that identifies a GEMM *request* except how to run it.

    ``padded`` is the block-padded per-group (M, K, N) — the launch shape
    the decision is for — or None when the resolution point does not know
    dims (the linear-path ``gemm_spec(groups=...)`` calls); shapeless keys
    aggregate over every shape that spec serves.

    ``epilogue`` and ``out_dtype`` are deliberately NOT part of the key:
    ``core.sparse_linear._mm`` sets both on the spec AFTER policy
    resolution (``spec.with_``), so keying on them would split the
    observation stream from the resolution stream — and neither changes
    the sparsity signature the decision rule reads."""
    block: Tuple[int, int, int]
    groups: int
    queue_builder: str
    padded: Optional[Tuple[int, int, int]] = None

    @property
    def stats_key(self) -> str:
        """The ``kernels.stats`` ring-buffer key this request's live-tile
        observations are recorded under."""
        shape = "x".join(map(str, self.padded)) if self.padded else "any"
        return ("autotune:" + "x".join(map(str, self.block))
                + f":g{self.groups}:{self.queue_builder}:{shape}")


def key_for(spec: "GemmSpec",
            dims: Optional[Tuple[int, int, int]] = None) -> AutotuneKey:
    """Build the cache key from a spec (its schedule — and the
    post-resolution ``epilogue``/``out_dtype`` fields — are ignored) and
    the per-group GEMM dims, padded to the spec's block."""
    padded = None
    if dims is not None:
        padded = tuple(ceil_to(d, b) for d, b in zip(dims, spec.block))
    return AutotuneKey(
        block=tuple(spec.block), groups=spec.groups,
        queue_builder=spec.queue_builder, padded=padded)


# ---------------------------------------------------------------------------
# Decisions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Decision:
    """One cached selection, plus the measurement it was made from."""
    key: AutotuneKey
    schedule: str
    block: Tuple[int, int, int]
    live_frac: Optional[float]      # mean out-live fraction at decision time
    operand_frac: Optional[float]
    samples: int                    # measured samples behind the decision
    event: str                      # "default" | "measured" | "retune"
    seq: int


def _refined_block(block: Tuple[int, int, int],
                   grans: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Halve each tile edge, floored to its mask-granularity multiple —
    the only block move that keeps caller-derived masks well-formed."""
    out = []
    for b, g in zip(block, grans):
        e = max(1, b // 2)
        out.append(max(g, ceil_to(e, g)))
    return tuple(out)


class AutotuneCache:
    """Per-(spec-minus-schedule, padded shape) schedule/tile decisions from
    measured live-tile stats, with drift re-evaluation and a full decision
    log (the traceability contract)."""

    def __init__(self, *, window: int = 32, min_samples: int = 4,
                 drift_threshold: float = 0.15,
                 compact_max_live: float = 0.5,
                 dense_min_live: float = 0.98,
                 refine_block_live: float = 0.75):
        self.window = window
        self.min_samples = min_samples
        self.drift_threshold = drift_threshold
        self.compact_max_live = compact_max_live
        self.dense_min_live = dense_min_live
        self.refine_block_live = refine_block_live
        self.hits = 0
        self.misses = 0
        self.retunes = 0
        self.log: List[dict] = []
        self._decisions: Dict[AutotuneKey, Decision] = {}
        # dispatch signature of a resolved spec → the key that resolved it,
        # so the dispatcher's observation lands in the same buffer the NEXT
        # resolve reads even when the tuned block differs from the key's
        # nominal request.  The signature ignores schedule/epilogue/
        # out_dtype (callers may ``with_`` those after resolution).
        self._spec_keys: Dict[Any, AutotuneKey] = {}
        self._seq = itertools.count()

    @staticmethod
    def _dispatch_sig(spec: "GemmSpec",
                      dims: Optional[Tuple[int, int, int]]) -> tuple:
        padded = None if dims is None else tuple(
            ceil_to(d, b) for d, b in zip(dims, spec.block))
        return (tuple(spec.block), spec.groups, spec.queue_builder, padded)

    # -- observation ----------------------------------------------------

    def observe(self, key: AutotuneKey, out_frac: float,
                operand_frac: float = 1.0) -> None:
        """Record one measured live-tile sample for ``key`` — and for its
        shapeless twin, so no-dims resolutions see shaped traffic too."""
        stats.record_live_tiles(key.stats_key, out_frac, operand_frac)
        if key.padded is not None:
            shapeless = dataclasses.replace(key, padded=None)
            stats.record_live_tiles(shapeless.stats_key, out_frac,
                                    operand_frac)

    def observe_dispatch(self, spec: "GemmSpec",
                         dims: Tuple[int, int, int], out_frac: float,
                         operand_frac: float = 1.0) -> None:
        """Dispatcher-side entry: attribute a concrete ``sparse_gemm``'s
        measured fractions to the key that resolved ``spec`` (falling back
        to a fresh key for specs this cache never saw)."""
        key = self._spec_keys.get(self._dispatch_sig(spec, dims)) \
            or self._spec_keys.get(self._dispatch_sig(spec, None)) \
            or key_for(spec, dims)
        self.observe(key, out_frac, operand_frac)

    # -- resolution -----------------------------------------------------

    def measured(self, key: AutotuneKey
                 ) -> Tuple[Optional[float], Optional[float], int]:
        return stats.live_tile_stats(key.stats_key, window=self.window)

    def resolve(self, key: AutotuneKey, default_spec: "GemmSpec", *,
                grans: Tuple[int, int, int] = (1, 1, 1),
                dims: Optional[Tuple[int, int, int]] = None) -> "GemmSpec":
        """The cache lookup: return ``default_spec`` retargeted onto the
        cached (or freshly decided) schedule/block for ``key``."""
        out_frac, op_frac, n = self.measured(key)
        prev = self._decisions.get(key)
        if prev is not None:
            newly_measured = prev.event == "default" \
                and n >= self.min_samples
            drifted = (prev.live_frac is not None and out_frac is not None
                       and abs(out_frac - prev.live_frac)
                       > self.drift_threshold)
            if not (newly_measured or drifted):
                self.hits += 1
                self._append_log(prev, "hit")
                return self._apply(prev, default_spec, key, dims)
            self.retunes += 1
            decision = self._decide(key, default_spec, out_frac, op_frac, n,
                                    grans, dims, event="retune")
        else:
            self.misses += 1
            event = "measured" if n >= self.min_samples else "default"
            decision = self._decide(key, default_spec, out_frac, op_frac, n,
                                    grans, dims, event=event)
        self._decisions[key] = decision
        self._append_log(decision, decision.event)
        return self._apply(decision, default_spec, key, dims)

    def _decide(self, key, default_spec, out_frac, op_frac, n, grans, dims,
                *, event: str) -> Decision:
        if n < self.min_samples or out_frac is None:
            # Not enough measurement yet: the static policy resolution
            # stands, recorded as an explicit (traceable) default.
            return Decision(key, default_spec.schedule,
                            tuple(default_spec.block), out_frac, op_frac, n,
                            "default", next(self._seq))
        if out_frac <= self.compact_max_live:
            schedule = "compact"
        elif min(out_frac, op_frac if op_frac is not None else 1.0) \
                >= self.dense_min_live:
            schedule = "dense"
        else:
            schedule = "predicated"
        block = tuple(default_spec.block)
        if schedule != "dense" and dims is not None \
                and out_frac >= self.refine_block_live:
            block = _refined_block(block, grans)
        return Decision(key, schedule, block, out_frac, op_frac, n, event,
                        next(self._seq))

    def _apply(self, decision: Decision, default_spec: "GemmSpec",
               key: AutotuneKey,
               dims: Optional[Tuple[int, int, int]]) -> "GemmSpec":
        spec = default_spec.with_(schedule=decision.schedule,
                                  block=decision.block)
        self._spec_keys[self._dispatch_sig(spec, dims)] = key
        return spec

    def _append_log(self, decision: Decision, event: str) -> None:
        self.log.append({
            "seq": decision.seq,
            "event": event,
            "key": decision.key.stats_key,
            "shape": "x".join(map(str, decision.key.padded))
            if decision.key.padded else "any",
            "groups": decision.key.groups,
            "schedule": decision.schedule,
            "block": "x".join(map(str, decision.block)),
            "live_frac": None if decision.live_frac is None
            else round(decision.live_frac, 4),
            "operand_frac": None if decision.operand_frac is None
            else round(decision.operand_frac, 4),
            "samples": decision.samples,
        })

    def decisions(self) -> Dict[AutotuneKey, Decision]:
        return dict(self._decisions)


# ---------------------------------------------------------------------------
# The process-global cache (mirrors the stats counters' lifetime)
# ---------------------------------------------------------------------------

_CACHE = AutotuneCache()


def get_cache() -> AutotuneCache:
    return _CACHE


def reset(**cache_kwargs) -> AutotuneCache:
    """Fresh global cache (optionally with non-default thresholds); the
    live-tile buffers in ``kernels.stats`` are cleared separately by
    ``stats.reset()``."""
    global _CACHE
    _CACHE = AutotuneCache(**cache_kwargs)
    return _CACHE


def resolve(default_spec: "GemmSpec", *,
            dims: Optional[Tuple[int, int, int]] = None,
            grans: Tuple[int, int, int] = (1, 1, 1)) -> "GemmSpec":
    """Module-level resolution entry used by ``SparsityPolicy.gemm_spec``."""
    key = key_for(default_spec, dims)
    return _CACHE.resolve(key, default_spec, grans=grans, dims=dims)


def observe_dispatch(spec: "GemmSpec", dims: Tuple[int, int, int],
                     out_frac: float, operand_frac: float = 1.0) -> None:
    """Dispatcher hook (``kernels.ops.sparse_gemm``)."""
    _CACHE.observe_dispatch(spec, dims, out_frac, operand_frac)


def log_rows() -> List[dict]:
    """The decision log — one row per resolve event, audit-table ready."""
    return list(_CACHE.log)
