"""Pallas TPU kernels for the gradient-output-sparsity technique.

Layout (per kernel): <name>.py — pl.pallas_call + BlockSpec tiling;
ops.py — jit'd public wrappers; ref.py — pure-jnp oracles.
"""
from . import ops, queue_builder, ref, stats  # noqa: F401
from .ops import (  # noqa: F401
    bitmap_scan,
    build_queue,
    grouped_masked_matmul,
    masked_matmul,
    relu_bwd_masked,
    relu_encode,
    weight_grad_masked,
)
