"""Pallas TPU kernels for the gradient-output-sparsity technique.

Layout (per kernel): <name>.py — pl.pallas_call + BlockSpec tiling;
ops.py — the spec-driven ``sparse_gemm`` dispatcher + jit'd public
wrappers; shapes.py — shared pad/tile helpers; ref.py — pure-jnp oracles.
"""
from . import autotune, ops, queue_builder, ref, shapes, stats  # noqa: F401
from .ops import (  # noqa: F401
    GemmMasks,
    GemmSpec,
    bitmap_scan,
    build_queue,
    relu_bwd_masked,
    relu_encode,
    sparse_gemm,
    weight_grad_masked,
)
