"""Fused ReLU forward + block-bitmap encode Pallas kernel.

The paper's Encoder unit (§4.1, Fig. 8a) produces non-zero offset indices of
a freshly computed feature map once per layer, amortized over O(M·k²) reuse.
The TPU analogue emits, in the same pass that applies the ReLU, a
*fine-granularity* block bitmap that the rest of the training step derives
every mask it needs from (FP input masks, BP output masks, WG transposed
masks) — so sparsity metadata is a free byproduct of the forward pass,
exactly as in the paper.

The bitmap granularity (gr, gc) is decoupled from the launch tile (lr, lc):
one kernel invocation covers an (lr, lc) slab of the activation and reduces
it to an (lr//gr, lc//gc) sub-bitmap with a single reshape-max, so even
per-row granularities (needed by the conv path, where the bitmap must stay
spatially addressable for im2col derivation) launch with a coarse grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _relu_encode_kernel(z_ref, y_ref, bm_ref, *, gr: int, gc: int):
    y = jnp.maximum(z_ref[...], jnp.zeros((), dtype=z_ref.dtype))
    y_ref[...] = y
    lr, lc = y.shape
    yb = y.reshape(lr // gr, gr, lc // gc, gc)
    # y >= 0 everywhere, so max > 0 <=> the sub-block has a live activation.
    bm_ref[...] = (jnp.max(yb.astype(jnp.float32), axis=(1, 3)) > 0) \
        .astype(jnp.int32)


def relu_encode_kernel(
    z: jnp.ndarray,
    *,
    bm: int,
    bn: int,
    lr: int = 0,
    lc: int = 0,
    interpret: bool = False,
):
    """Returns (relu(z), bitmap) with bitmap shape (M//bm, N//bn) int32.

    (bm, bn) is the BITMAP granularity; (lr, lc) the launch tile (defaults:
    whole array — callers size it; the ops wrapper picks ~8-row slabs so
    fine granularities never explode the grid).
    """
    m, n = z.shape
    lr = lr or m
    lc = lc or n
    assert m % lr == 0 and n % lc == 0, (z.shape, lr, lc)
    assert lr % bm == 0 and lc % bn == 0, (lr, lc, bm, bn)
    ni, nj = m // lr, n // lc
    fr, fc = lr // bm, lc // bn
    fn = pl.pallas_call(
        functools.partial(_relu_encode_kernel, gr=bm, gc=bn),
        grid=(ni, nj),
        in_specs=[pl.BlockSpec((lr, lc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((lr, lc), lambda i, j: (i, j)),
            pl.BlockSpec((fr, fc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), z.dtype),
            jax.ShapeDtypeStruct((m // bm, n // bn), jnp.int32),
        ],
        interpret=interpret,
    )
    return fn(z)
