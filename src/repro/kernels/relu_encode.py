"""Fused ReLU forward + block-bitmap encode Pallas kernel.

The paper's Encoder unit (§4.1, Fig. 8a) produces non-zero offset indices of
a freshly computed feature map once per layer, amortized over O(M·k²) reuse.
The TPU analogue emits, in the same pass that applies the ReLU, the
block-granular bitmap that the backward pass will consume for OUTPUT
sparsity — so sparsity metadata is a free byproduct of the forward pass,
exactly as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _relu_encode_kernel(z_ref, y_ref, bm_ref):
    y = jnp.maximum(z_ref[...], jnp.zeros((), dtype=z_ref.dtype))
    y_ref[...] = y
    bm_ref[0, 0] = jnp.any(y > 0).astype(jnp.int32)


def relu_encode_kernel(
    z: jnp.ndarray,
    *,
    bm: int,
    bn: int,
    interpret: bool = False,
):
    """Returns (relu(z), bitmap) with bitmap shape (M//bm, N//bn) int32."""
    m, n = z.shape
    assert m % bm == 0 and n % bn == 0, (z.shape, bm, bn)
    ni, nj = m // bm, n // bn
    fn = pl.pallas_call(
        _relu_encode_kernel,
        grid=(ni, nj),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), z.dtype),
            jax.ShapeDtypeStruct((ni, nj), jnp.int32),
        ],
        interpret=interpret,
    )
    return fn(z)
