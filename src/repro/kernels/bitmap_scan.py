"""Pallas block-any-nonzero bitmap scan — the OFF-hot-path encoder.

Every training-step tensor now gets its bitmap from its PRODUCER:
``kernels.relu_encode`` makes the activation bitmap a free byproduct of
the forward ReLU, and the ``bitmap_emit`` GEMM epilogue stage
(``kernels.masked_matmul``, staged via ``GemmSpec.epilogue``) thresholds
each dy accumulator tile at writeback — so the ROADMAP "TPU-native
scan_bitmap" item's endgame landed and ``scan_pallas:*`` is identically
zero on the training hot path.  This standalone kernel survives for the
two jobs with no producing op to fuse into: the OPT-IN entry scan of raw
signed model inputs (``SparsityPolicy.scan_signed_inputs``) and the
numerical reference that emit-epilogue tests compare against.

Same granularity/launch-slab decoupling as relu_encode: one grid step
covers an (lr, lc) slab and reduces it with a single reshape-max, so the
per-row granularities the conv path needs stay cheap to launch.  Signed
data ⇒ the liveness predicate is ``|x| > 0``, not ``x > 0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _bitmap_scan_kernel(x_ref, bm_ref, *, gr: int, gc: int):
    x = x_ref[...].astype(jnp.float32)
    lr, lc = x.shape
    xb = jnp.abs(x).reshape(lr // gr, gr, lc // gc, gc)
    bm_ref[...] = (jnp.max(xb, axis=(1, 3)) > 0).astype(jnp.int32)


def bitmap_scan_kernel(
    x: jnp.ndarray,
    *,
    bm: int,
    bn: int,
    lr: int = 0,
    lc: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns the (M//bm, N//bn) int32 any-nonzero bitmap of signed ``x``.

    (bm, bn) is the BITMAP granularity; (lr, lc) the launch tile (defaults:
    whole array — the ops wrapper picks ~8-row slabs).
    """
    m, n = x.shape
    lr = lr or m
    lc = lc or n
    assert m % lr == 0 and n % lc == 0, (x.shape, lr, lc)
    assert lr % bm == 0 and lc % bn == 0, (lr, lc, bm, bn)
    ni, nj = m // lr, n // lc
    fr, fc = lr // bm, lc // bn
    fn = pl.pallas_call(
        functools.partial(_bitmap_scan_kernel, gr=bm, gc=bn),
        grid=(ni, nj),
        in_specs=[pl.BlockSpec((lr, lc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((fr, fc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m // bm, n // bn), jnp.int32),
        interpret=interpret,
    )
    return fn(x)
