"""Public, jit-friendly wrappers around the Pallas kernels.

Handles:
  * automatic interpret-mode selection (CPU backend → interpret=True, so the
    whole framework is testable in this container while targeting TPU),
  * block-alignment padding (MXU-aligned defaults bm=bk=bn=128; padded
    blocks are marked inactive so they are skipped, not computed),
  * host-side bitmap derivation from dense operands / ReLU masks,
  * the compact (work-redistribution) launch path, including the active-
    coordinate queue construction and the scatter back to dense layout.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref, stats
from .bitmap_scan import bitmap_scan_kernel
from .masked_matmul import (
    compact_masked_matmul_kernel,
    grouped_compact_masked_matmul_kernel,
    grouped_masked_matmul_kernel,
    masked_matmul_kernel,
)
from .queue_builder import build_queue_kernel
from .relu_encode import relu_encode_kernel

# MXU-native tile. Tests sweep smaller tiles in interpret mode.
DEFAULT_BLOCK = (128, 128, 128)

def _parse_version(v: str):
    """Leading-digit parse per component: '0.4.27rc1' → (0, 4, 27); any
    unparseable component compares as 0 (never an import-time crash)."""
    import re
    out = []
    for part in v.split(".")[:3]:
        m = re.match(r"\d+", part)
        out.append(int(m.group()) if m else 0)
    return tuple(out)


_JAX_VERSION = _parse_version(jax.__version__)


def _stable_argsort_desc(flat: jnp.ndarray) -> jnp.ndarray:
    """Stable descending argsort of a {0,1} vector (active indices first,
    row-major within each class) — the retained O(T log T) queue-builder
    reference.  ``stable=`` only exists from jax 0.4.27; earlier releases
    sort stably by default, so the kwarg is version-gated, not assumed."""
    if _JAX_VERSION >= (0, 4, 27):
        return jnp.argsort(-flat, stable=True)
    return jnp.argsort(-flat)  # pre-0.4.27 argsort is stable by default


def build_queue(
    bitmap: jnp.ndarray,
    *,
    capacity: int,
    builder: str = "prefix_sum",
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Active-tile queue ``(ii, jj, n_live)`` from a (Mb, Nb) tile bitmap.

    Queue order is the WDU's "lexicographically smallest state tuple first"
    — row-major (i, j); ``core.workredist.static_queue_order`` is the
    reference.  ``n_live`` (1,) is the TRUE set-bit count (may exceed
    ``capacity``; slots past it are zero-padded).

    builder="prefix_sum" (default): Pallas blockwise exclusive-prefix-sum
    stream compaction — O(T), no sort on the critical path.
    builder="argsort": the seed's O(T log T) sort, kept as the reference
    and fallback.  Each construction is counted by ``stats`` as
    ``queue:<builder>``.
    """
    mb, nb = bitmap.shape
    stats.record(f"queue:{builder}")
    if builder == "argsort":
        flat = bitmap.reshape(-1)
        order = _stable_argsort_desc(flat)[:capacity]
        if order.shape[0] < capacity:           # capacity may exceed T
            order = jnp.pad(order, (0, capacity - order.shape[0]))
        ii = (order // nb).astype(jnp.int32)
        jj = (order % nb).astype(jnp.int32)
        # Dead slots must carry valid (in-range) coords for the consumer's
        # gathers; zero them like the prefix-sum builder does.
        live = jnp.arange(capacity) < flat.sum()
        ii = jnp.where(live, ii, 0)
        jj = jnp.where(live, jj, 0)
        return ii, jj, flat.sum().reshape(1)
    if builder != "prefix_sum":
        raise ValueError(f"unknown queue builder: {builder!r}")
    return build_queue_kernel(bitmap, capacity=capacity,
                              interpret=_use_interpret(interpret))


def _use_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _ceil_to(v: int, b: int) -> int:
    return (v + b - 1) // b * b


def _block_bitmap(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    return ref.block_any_nonzero(x, bm, bn)


def _ones_bitmap(nb0: int, nb1: int) -> jnp.ndarray:
    return jnp.ones((nb0, nb1), jnp.int32)


def masked_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    out_mask: Optional[jnp.ndarray] = None,
    a_mask: Optional[jnp.ndarray] = None,
    b_mask: Optional[jnp.ndarray] = None,
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=jnp.float32,
    compact: bool = False,
    max_active_blocks: Optional[int] = None,
    queue_builder: str = "prefix_sum",
    epilogue_mult: Optional[jnp.ndarray] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Block-sparse ``a @ b`` with output/input sparsity skipping.

    Masks are block bitmaps (see kernels docstring); ``None`` means dense on
    that axis pair.  Result equals ``(a @ b) * expand(out_mask)`` exactly.

    ``compact=True`` routes through the work-redistribution schedule: the
    grid walks only active output tiles (queue capacity
    ``max_active_blocks``, default = all tiles).  If more tiles are live
    than the queue holds, the call falls back to the predicated schedule —
    never a silent truncation.  ``queue_builder`` selects how the queue is
    constructed: ``"prefix_sum"`` (default) is the on-device Pallas stream
    compaction, ``"argsort"`` the retained sort-based reference.

    ``epilogue_mult`` (M, N): fused Hadamard applied to the output inside
    the kernel (the backward σ′ multiply), saving a full-size VPU pass.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = block
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    ni, nk, nj = mp // bm, kp // bk, np_ // bn

    a_p = _pad_to(a, mp, kp)
    b_p = _pad_to(b, kp, np_)
    mult_p = None
    if epilogue_mult is not None:
        assert epilogue_mult.shape == (m, n), (epilogue_mult.shape, (m, n))
        mult_p = _pad_to(epilogue_mult.astype(jnp.float32), mp, np_)

    def _pad_mask(mask, nb0, nb1):
        if mask is None:
            return _ones_bitmap(nb0, nb1)
        mask = mask.astype(jnp.int32)
        p0, p1 = nb0 - mask.shape[0], nb1 - mask.shape[1]
        if p0 or p1:
            mask = jnp.pad(mask, ((0, p0), (0, p1)))
        return mask

    om = _pad_mask(out_mask, ni, nj)
    am = _pad_mask(a_mask, ni, nk)
    bmask = _pad_mask(b_mask, nk, nj)

    itp = _use_interpret(interpret)

    def _predicated():
        return masked_matmul_kernel(
            a_p, b_p, om, am, bmask,
            bm=bm, bk=bk, bn=bn, out_dtype=out_dtype,
            epilogue_mult=mult_p, interpret=itp,
        )

    if compact:
        s_cap = max_active_blocks if max_active_blocks is not None else ni * nj
        # Active-queue construction in the WDU's "lexicographically smallest
        # state tuple first" order — row-major (i, j).  The default builder
        # is the O(T) Pallas prefix-sum compaction; "argsort" keeps the
        # seed's O(T log T) sort as a reference/fallback.
        ii, jj, n_live_v = build_queue(
            om, capacity=s_cap, builder=queue_builder, interpret=itp)
        n_live = n_live_v[0]
        n_active = jnp.minimum(n_live, s_cap).reshape(1)

        def _compact():
            compacted = compact_masked_matmul_kernel(
                a_p, b_p, ii, jj, n_active, am, bmask,
                bm=bm, bk=bk, bn=bn, out_dtype=out_dtype,
                epilogue_mult=mult_p, interpret=itp,
            )
            # Scatter the queue back to dense tile layout.  Padding steps
            # carry zero tiles at coords (ii, jj) of dead queue slots — we
            # direct dead slots at (0, 0) via scatter-ADD so they are no-ops.
            live = (jnp.arange(s_cap) < n_active[0]).astype(out_dtype)
            masked = compacted * live[:, None, None]
            si = jnp.where(jnp.arange(s_cap) < n_active[0], ii, 0)
            sj = jnp.where(jnp.arange(s_cap) < n_active[0], jj, 0)
            out_tiles = jnp.zeros((ni, nj, bm, bn), out_dtype)
            out_tiles = out_tiles.at[si, sj].add(masked)
            return out_tiles.transpose(0, 2, 1, 3).reshape(mp, np_)

        if s_cap >= ni * nj:
            out = _compact()          # queue provably cannot overflow
        else:
            # Queue-capacity overflow would silently drop live tiles.  The
            # live count is a traced value, so detect at runtime and fall
            # back to the predicated (full-grid) schedule — exact always.
            out = jax.lax.cond(n_live > s_cap, _predicated, _compact)
    else:
        out = _predicated()
    return out[:m, :n]


def grouped_masked_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    out_mask: Optional[jnp.ndarray] = None,
    a_mask: Optional[jnp.ndarray] = None,
    b_mask: Optional[jnp.ndarray] = None,
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=jnp.float32,
    compact: bool = False,
    max_active_blocks: Optional[int] = None,
    queue_builder: str = "prefix_sum",
    epilogue_mult: Optional[jnp.ndarray] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Block-sparse batched ``a[g] @ b[g]`` over a leading group axis — the
    GEMM form of grouped/depthwise convs.

    Operands are (G, M, K) and (G, K, N); masks carry a leading G axis and
    are per-group block bitmaps with exactly ``masked_matmul``'s semantics
    — groups never mix (the group-boundary contract).  ``compact=True``
    builds ONE queue spanning all groups: the (G, Mb, Nb) out_mask is
    flattened row-major — lexicographic ⟨g, i, j⟩, the WDU dispatch order
    lifted to the group axis — and compacted by the same builder backends
    as the 2-D path, so depthwise layers (many groups, few tiles each)
    still launch a single uniform work stream.  Overflow falls back to the
    grouped predicated schedule — never a silent truncation.
    """
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2, (a.shape, b.shape)
    bm, bk, bn = block
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    ni, nk, nj = mp // bm, kp // bk, np_ // bn

    def _pad3(x, d1, d2):
        p1, p2 = d1 - x.shape[1], d2 - x.shape[2]
        if p1 == 0 and p2 == 0:
            return x
        return jnp.pad(x, ((0, 0), (0, p1), (0, p2)))

    a_p = _pad3(a, mp, kp)
    b_p = _pad3(b, kp, np_)
    mult_p = None
    if epilogue_mult is not None:
        assert epilogue_mult.shape == (g, m, n), epilogue_mult.shape
        mult_p = _pad3(epilogue_mult.astype(jnp.float32), mp, np_)

    def _pad_mask3(mask, nb0, nb1):
        if mask is None:
            return jnp.ones((g, nb0, nb1), jnp.int32)
        mask = mask.astype(jnp.int32)
        return _pad3(mask, nb0, nb1)

    om = _pad_mask3(out_mask, ni, nj)
    am = _pad_mask3(a_mask, ni, nk)
    bmask = _pad_mask3(b_mask, nk, nj)

    itp = _use_interpret(interpret)

    def _predicated():
        return grouped_masked_matmul_kernel(
            a_p, b_p, om, am, bmask,
            bm=bm, bk=bk, bn=bn, out_dtype=out_dtype,
            epilogue_mult=mult_p, interpret=itp,
        )

    if compact:
        s_cap = max_active_blocks if max_active_blocks is not None \
            else g * ni * nj
        # One queue over all groups: flatten (G, Mb, Nb) to (G·Mb, Nb) so
        # the row-major builder order IS lexicographic (g, i, j); decode the
        # group coordinate back out of the fused row index.
        fi, jj, n_live_v = build_queue(
            om.reshape(g * ni, nj), capacity=s_cap, builder=queue_builder,
            interpret=itp)
        gg = fi // ni
        ii = fi % ni
        n_live = n_live_v[0]
        n_active = jnp.minimum(n_live, s_cap).reshape(1)

        def _compact():
            compacted = grouped_compact_masked_matmul_kernel(
                a_p, b_p, gg, ii, jj, n_active, am, bmask,
                bm=bm, bk=bk, bn=bn, out_dtype=out_dtype,
                epilogue_mult=mult_p, interpret=itp,
            )
            live = (jnp.arange(s_cap) < n_active[0]).astype(out_dtype)
            masked = compacted * live[:, None, None]
            sg = jnp.where(jnp.arange(s_cap) < n_active[0], gg, 0)
            si = jnp.where(jnp.arange(s_cap) < n_active[0], ii, 0)
            sj = jnp.where(jnp.arange(s_cap) < n_active[0], jj, 0)
            out_tiles = jnp.zeros((g, ni, nj, bm, bn), out_dtype)
            out_tiles = out_tiles.at[sg, si, sj].add(masked)
            return out_tiles.transpose(0, 1, 3, 2, 4).reshape(g, mp, np_)

        if s_cap >= g * ni * nj:
            out = _compact()
        else:
            out = jax.lax.cond(n_live > s_cap, _predicated, _compact)
    else:
        out = _predicated()
    return out[:, :m, :n]


def bitmap_scan(
    x: jnp.ndarray,
    *,
    block: Tuple[int, int] = (DEFAULT_BLOCK[0], DEFAULT_BLOCK[2]),
    kind: str = "act",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pallas block-any-nonzero bitmap of SIGNED data at granularity
    ``block`` — the encoder for tensors with no ReLU to fuse into (raw
    inputs, incoming gradients).  Pads, launches, unpads.

    Counted under the distinct ``scan_pallas:<kind>`` stats key so the
    audit can tell TPU-native scans from the retained XLA-reference scans
    (``scan:<kind>``); both still count toward the one-computation-per-
    tensor-per-step budget.
    """
    m, n = x.shape
    bm, bn = block
    lr = bm * max(1, -(-8 // bm))
    mp, np_ = _ceil_to(m, lr), _ceil_to(n, bn)
    x_p = _pad_to(x, mp, np_)
    stats.record(f"scan_pallas:{kind}")
    bitmap = bitmap_scan_kernel(x_p, bm=bm, bn=bn, lr=lr, lc=np_,
                                interpret=_use_interpret(interpret))
    return bitmap[: _ceil_to(m, bm) // bm, :]


def relu_encode(
    z: jnp.ndarray,
    *,
    block: Tuple[int, int] = (DEFAULT_BLOCK[0], DEFAULT_BLOCK[2]),
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused relu(z) + block bitmap at granularity ``block``.

    Pads, launches, unpads.  The launch tile is decoupled from the bitmap
    granularity (≥8 rows per grid step), so fine granularities — down to
    per-row bitmaps, which the conv path needs for im2col-derivable
    metadata — stay cheap to launch.

    This is THE forward-pass bitmap computation: one fused pass per
    activation per step; every downstream mask is derived from its result.
    """
    m, n = z.shape
    bm, bn = block
    # Launch slab: a multiple of the bitmap granularity covering >=8 rows.
    lr = bm * max(1, -(-8 // bm))
    mp, np_ = _ceil_to(m, lr), _ceil_to(n, bn)
    z_p = _pad_to(z, mp, np_)
    stats.record("encode:act")
    y, bitmap = relu_encode_kernel(z_p, bm=bm, bn=bn, lr=lr, lc=np_,
                                   interpret=_use_interpret(interpret))
    return y[:m, :n], bitmap[: _ceil_to(m, bm) // bm, :]


def relu_bwd_masked(
    dy: jnp.ndarray,          # (M, K) δ_post — gradient arriving from layer above
    w_t: jnp.ndarray,         # (K, N) Wᵀ of the producer layer
    relu_mask: jnp.ndarray,   # (M, N) {0,1} σ'(z) captured in the forward pass
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    use_input_sparsity: bool = True,
    use_output_sparsity: bool = True,
    compact: bool = False,
    queue_builder: str = "prefix_sum",
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """δ_pre = (δ_post @ Wᵀ) ⊙ σ'(z) with block skipping — the paper's core op.

    OUTPUT sparsity: tiles where σ'(z) is all-zero are never computed.
    INPUT sparsity: K-tiles of δ_post that are all-zero are skipped.
    Partially-live tiles are computed densely then Hadamard-masked — exact.
    """
    bm, bk, bn = block
    m, n = relu_mask.shape
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    mask_p = _pad_to(relu_mask.astype(jnp.float32), mp, np_)
    out_mask = _block_bitmap(mask_p, bm, bn) if use_output_sparsity else None

    a_mask = None
    if use_input_sparsity:
        kp = _ceil_to(dy.shape[1], bk)
        a_mask = _block_bitmap(_pad_to(dy.astype(jnp.float32), mp, kp), bm, bk)

    # Fused σ′-Hadamard epilogue: partially-live tiles are masked inside the
    # kernel at writeback (free on the ASIC's output bitmap; zero extra HBM
    # round-trips here).
    return masked_matmul(
        dy, w_t, out_mask=out_mask, a_mask=a_mask, b_mask=None,
        block=block, out_dtype=out_dtype, compact=compact,
        queue_builder=queue_builder,
        epilogue_mult=relu_mask.astype(jnp.float32), interpret=interpret,
    )


def weight_grad_masked(
    x_t: jnp.ndarray,        # (N, M) Xᵀ — activations (sparse post-ReLU)
    dy: jnp.ndarray,         # (N, K) δ — gradient (sparse post-ReLU-Hadamard)
    *,
    block: Tuple[int, int, int] = DEFAULT_BLOCK,
    use_input_sparsity: bool = True,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """dW = Xᵀ @ δ with INPUT sparsity on both operands (the paper's WG stage).

    There is no output sparsity in WG — every weight gradient entry is
    needed — but the contraction (batch·spatial) dimension tiles where
    either operand is all-zero are skipped.
    """
    bm, bk, bn = block
    a_mask = b_mask = None
    if use_input_sparsity:
        mp = _ceil_to(x_t.shape[0], bm)
        kp = _ceil_to(x_t.shape[1], bk)
        np_ = _ceil_to(dy.shape[1], bn)
        a_mask = _block_bitmap(_pad_to(x_t.astype(jnp.float32), mp, kp), bm, bk)
        b_mask = _block_bitmap(_pad_to(dy.astype(jnp.float32), kp, np_), bk, bn)
    return masked_matmul(
        x_t, dy, out_mask=None, a_mask=a_mask, b_mask=b_mask,
        block=block, out_dtype=out_dtype, interpret=interpret,
    )
