"""Public, jit-friendly wrappers around the Pallas kernels.

THE masked-GEMM entry point is ``sparse_gemm(a, b, masks, spec)``:

  * ``GemmSpec`` is a frozen, hashable request object — tile shape, group
    count, schedule ∈ {predicated, compact, dense}, a composable tuple of
    epilogue stages ⊆ {sigma_prime, bitmap_emit}, queue builder, queue
    capacity, output dtype.  It is static metadata: shardable, cacheable,
    and printable, where the old API threaded seven loose kwargs through
    every layer.
  * ``GemmMasks`` carries the (out, a, b) block bitmaps; ``None`` on any
    slot means dense on that axis pair.
  * The dispatcher owns the pad / queue / overflow-fallback / scatter
    contract in EXACTLY ONE place: 2-D operands are lowered as the G=1
    special case of the grouped engine, so every GEMM in the system —
    linear, conv im2col, grouped/depthwise, WG — shares one tuned
    implementation (the SparseTrain/TensorDash "single uniform sparse
    dataflow" lesson).

Handles:
  * automatic interpret-mode selection (CPU backend → interpret=True, so the
    whole framework is testable in this container while targeting TPU),
  * block-alignment padding (MXU-aligned defaults bm=bk=bn=128; padded
    blocks are marked inactive so they are skipped, not computed),
  * the compact (work-redistribution) launch path, including the active-
    coordinate queue construction and the scatter back to dense layout,
  * a ``schedule="dense"`` lowering (dense compute + output masking) that
    is numerically identical to the kernels — the xla_ref policy path.

With the ``bitmap_emit`` epilogue stage, a dispatch also returns the
packed any-nonzero bitmap of its own output — emitted at accumulator
writeback, so backward-pass metadata (the dy bitmap) is a free byproduct
of the GEMM that produced the dy, exactly as ``relu_encode`` makes the
activation bitmap a byproduct of the forward ReLU.  Every dispatch is
counted by ``kernels.stats`` under ``gemm:<schedule>:<g>`` (plus
``emit:grad`` per emitted bitmap).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from . import queue_builder as _queue_builder
from . import ref, stats
from .bitmap_scan import bitmap_scan_kernel
from .masked_matmul import (
    grouped_compact_masked_matmul_kernel,
    grouped_masked_matmul_kernel,
)
from .relu_encode import relu_encode_kernel
from .shapes import (
    block_bitmap, ceil_to, grid_shape, pad3, pad_mask3, pad_to,
)

# MXU-native tile. Tests sweep smaller tiles in interpret mode.
DEFAULT_BLOCK = (128, 128, 128)

SCHEDULES = ("predicated", "compact", "dense")
# Composable epilogue stages, in canonical application order: the σ′
# Hadamard first, then bitmap emission over the POST-σ′ values (the
# emitted bits must describe exactly what is written back).
EPILOGUE_STAGES = ("sigma_prime", "bitmap_emit")


def normalize_epilogue(epilogue) -> Tuple[str, ...]:
    """Canonicalize an epilogue declaration to a stage tuple.

    Accepts the legacy strings (``"none"``/``"sigma_prime"``), ``None``,
    or any iterable of stage names; returns the stages in canonical order
    with duplicates rejected."""
    if epilogue is None or epilogue == "none" or epilogue == ():
        return ()
    stages = (epilogue,) if isinstance(epilogue, str) else tuple(epilogue)
    bad = [s for s in stages if s not in EPILOGUE_STAGES]
    if bad or len(set(stages)) != len(stages):
        raise ValueError(
            f"epilogue stages must be unique and drawn from "
            f"{EPILOGUE_STAGES}, got {epilogue!r}")
    return tuple(s for s in EPILOGUE_STAGES if s in stages)


def _use_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def build_queue(
    bitmap: jnp.ndarray,
    *,
    capacity: int,
    builder: str = "prefix_sum",
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Active-tile queue from a tile bitmap — re-export of
    ``kernels.queue_builder.build_queue`` with auto interpret resolution
    (the builder dispatch itself lives next to the prefix-sum kernel)."""
    return _queue_builder.build_queue(
        bitmap, capacity=capacity, builder=builder,
        interpret=_use_interpret(interpret))


# ---------------------------------------------------------------------------
# The request objects
# ---------------------------------------------------------------------------

class GemmMasks(NamedTuple):
    """Block bitmaps for one GEMM; ``None`` ⇒ dense on that axis pair.

    2-D request (G=1): out (Mb, Nb), a (Mb, Kb), b (Kb, Nb).
    Grouped request:   each mask carries a leading G axis.
    """
    out: Optional[jnp.ndarray] = None
    a: Optional[jnp.ndarray] = None
    b: Optional[jnp.ndarray] = None


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """One masked GEMM, fully described as static metadata.

    schedule:
      * "predicated" — full (G, Mb, Nb, Kb) grid; each step guards its MXU
        issue on the masks (the paper's baseline sparse PE).
      * "compact"    — work-redistribution: ONE queue of active (g, i, j)
        tiles spanning all groups (lexicographic WDU order), built by
        ``queue_builder``; overflow beyond ``max_active_blocks`` falls back
        to the predicated schedule at runtime — never a silent truncation.
      * "dense"      — no Pallas launch: dense compute + output-mask +
        epilogue, numerically identical (the xla_ref policy path; operand
        masks are accounted by the cost model, not consumed).

    epilogue: a tuple of composable stages (normalized from the legacy
    strings ``"none"``/``"sigma_prime"``), applied at accumulator
    writeback in canonical order:
      * ``"sigma_prime"`` — Hadamard with an (M, N) multiplier (the
        backward σ′ multiply).  The multiplier itself is DATA and is
        passed to ``sparse_gemm(..., epilogue_mult=)``; the spec only
        declares the shape of the launch, so it stays hashable/static.
      * ``"bitmap_emit"`` — reduce the written (post-σ′) values to their
        (``emit_gran``) any-nonzero bitmap in the same writeback, so the
        producing GEMM hands its consumer the mask for free (no separate
        ``bitmap_scan`` pass).  ``sparse_gemm`` then returns
        ``(out, bitmap)``.

    emit_gran: the (er, ec) bitmap granularity, required iff
    ``"bitmap_emit"`` is staged; must divide the (bm, bn) tile edges.

    max_active_blocks: compact-queue capacity (None → all tiles, which
    provably cannot overflow).  interpret: None → auto (CPU ⇒ True).

    origin records WHO resolved the spec — ``"policy"`` when it came out of
    ``SparsityPolicy.gemm_spec()`` (the one sanctioned resolution point),
    ``"adhoc"`` otherwise.  It is provenance metadata for the static
    analyzer's SPEC_UNRESOLVED check, deliberately excluded from eq/hash so
    a policy-resolved spec and its ad-hoc twin stay interchangeable as jit
    cache keys.
    """
    block: Tuple[int, int, int] = DEFAULT_BLOCK
    groups: int = 1
    schedule: str = "predicated"
    epilogue: Tuple[str, ...] = ()
    emit_gran: Optional[Tuple[int, int]] = None
    queue_builder: str = "prefix_sum"
    max_active_blocks: Optional[int] = None
    out_dtype: Any = jnp.float32
    interpret: Optional[bool] = None
    origin: str = dataclasses.field(default="adhoc", compare=False)

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule must be one of {SCHEDULES}, got {self.schedule!r}")
        object.__setattr__(self, "epilogue",
                           normalize_epilogue(self.epilogue))
        if self.groups < 1:
            raise ValueError(f"groups must be >= 1, got {self.groups}")
        if len(self.block) != 3 or any(e < 1 for e in self.block):
            raise ValueError(f"block must be 3 positive edges: {self.block}")
        if self.emits_bitmap:
            bm, _, bn = self.block
            if (self.emit_gran is None or len(self.emit_gran) != 2
                    or bm % self.emit_gran[0] or bn % self.emit_gran[1]):
                raise ValueError(
                    f"bitmap_emit epilogue requires emit_gran dividing "
                    f"(bm, bn)={bm, bn}, got {self.emit_gran!r}")
        elif self.emit_gran is not None:
            raise ValueError(
                f"emit_gran={self.emit_gran!r} without a bitmap_emit "
                f"epilogue stage")

    def with_(self, **kw) -> "GemmSpec":
        return dataclasses.replace(self, **kw)

    @property
    def fuses_mult(self) -> bool:
        """Whether the ``sigma_prime`` Hadamard stage is declared."""
        return "sigma_prime" in self.epilogue

    @property
    def emits_bitmap(self) -> bool:
        """Whether the ``bitmap_emit`` stage is declared (dispatch then
        returns ``(out, bitmap)``)."""
        return "bitmap_emit" in self.epilogue

    @property
    def stats_key(self) -> str:
        """The normalized per-launch counter key: ``gemm:<schedule>:<g>``."""
        return f"gemm:{self.schedule}:{self.groups}"

    def launch_geometry(self, m: int, k: int, n: int) -> dict:
        """Static launch geometry this spec resolves to for per-group dims
        (M, K, N) — the single source of truth the dispatcher pads/launches
        by, and what ``benchmarks/kernel_audit.launch_shape_audit`` pins so
        future spec changes can't silently regress launch shapes."""
        bm, bk, bn = self.block
        ni, nk, nj = grid_shape((m, k, n), self.block)
        g = self.groups
        geom = {
            "schedule": self.schedule,
            "groups": g,
            "block": (bm, bk, bn),
            "padded": (g, ni * bm, nk * bk, nj * bn),
            "queue_capacity": 0,
            "grid": (),
        }
        if self.schedule == "dense":
            return geom
        predicated_grid = (g, ni, nj, nk)
        if self.schedule == "compact":
            cap = self.max_active_blocks
            geom["queue_capacity"] = g * ni * nj if cap is None else cap
            geom["grid"] = (geom["queue_capacity"], nk)
            geom["fallback_grid"] = predicated_grid
        else:
            geom["grid"] = predicated_grid
        return geom


MasksLike = Union[GemmMasks, Sequence[Optional[jnp.ndarray]], None]


def _as_masks(masks: MasksLike) -> GemmMasks:
    if masks is None:
        return GemmMasks()
    if isinstance(masks, GemmMasks):
        return masks
    return GemmMasks(*masks)


# ---------------------------------------------------------------------------
# The dispatcher — the ONE pad/queue/overflow-fallback/scatter implementation
# ---------------------------------------------------------------------------

# Trace-time dispatch events for the static analyzer's SPEC_UNRESOLVED
# check: while a ``collect_gemm_events()`` context is active, every
# ``sparse_gemm`` dispatch appends its spec here.  Tracing is single-
# threaded per process, so a plain module slot (not a contextvar) is enough.
_GEMM_EVENTS: Optional[List[GemmSpec]] = None

# Fault-injection tap (repro/runtime/faults.py): when a hook is installed,
# every dispatch offers named values for tampering — the chaos harness uses
# it to shrink a compact queue's capacity ("gemm:spec") or flip bits in an
# emitted bitmap ("gemm:emit_bits") without the kernels layer importing the
# runtime layer.  None (the default) is a zero-cost passthrough.
_TAMPER_HOOK = None


def set_tamper_hook(fn):
    """Install (or, with None, remove) the fault-injection tamper hook;
    returns the previous hook so callers can restore it."""
    global _TAMPER_HOOK
    prev, _TAMPER_HOOK = _TAMPER_HOOK, fn
    return prev


def _tamper(site: str, value):
    return value if _TAMPER_HOOK is None else _TAMPER_HOOK(site, value)


@contextlib.contextmanager
def collect_gemm_events():
    """Record every ``sparse_gemm`` dispatch (its ``GemmSpec``) traced or
    executed inside the context — the audit traces a model step under this
    and then asserts each spec's provenance (``origin == "policy"``)."""
    global _GEMM_EVENTS
    prev, _GEMM_EVENTS = _GEMM_EVENTS, []
    try:
        yield _GEMM_EVENTS
    finally:
        _GEMM_EVENTS = prev


def sparse_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    masks: MasksLike = None,
    spec: Optional[GemmSpec] = None,
    *,
    epilogue_mult: Optional[jnp.ndarray] = None,
):
    """Block-sparse GEMM with output/input sparsity skipping — the single
    entry point for every masked GEMM in the system.

    2-D request: ``a`` (M, K) @ ``b`` (K, N) with ``spec.groups == 1`` —
    lowered as the G=1 special case of the grouped engine.
    Grouped request: ``a`` (G, M, K) @ ``b`` (G, K, N) batched per group
    (``spec.groups == G``); masks carry a leading G axis and groups never
    mix (the group-boundary contract).

    Result equals the dense product masked by ``expand(masks.out)`` (and
    Hadamard-multiplied by ``epilogue_mult`` when the spec stages
    ``sigma_prime``) exactly — skipping is lossless by construction.

    With the ``bitmap_emit`` stage, returns ``(out, bitmap)`` where
    ``bitmap`` is the packed (⌈M/er⌉, ⌈N/ec⌉) int32 any-nonzero bitmap of
    the returned (post-epilogue) values at ``spec.emit_gran`` — emitted at
    accumulator writeback, identical to a fresh ``bitmap_scan`` of the
    output, and counted as ``emit:grad`` (a bitmap computation, not a
    rescan).
    """
    spec = GemmSpec() if spec is None else spec
    spec = _tamper("gemm:spec", spec)
    masks = _as_masks(masks)
    if (epilogue_mult is not None) != spec.fuses_mult:
        raise ValueError(
            f"spec.epilogue={spec.epilogue!r} but epilogue_mult "
            f"{'is' if epilogue_mult is not None else 'is not'} provided")
    grouped_in = a.ndim == 3
    if not grouped_in:
        if spec.groups != 1:
            raise ValueError(
                f"2-D operands require spec.groups == 1, got {spec.groups}")
        a3, b3 = a[None], b[None]
        masks = GemmMasks(*(m if m is None else m[None] for m in masks))
        mult3 = None if epilogue_mult is None else epilogue_mult[None]
    else:
        if a.shape[0] != spec.groups:
            raise ValueError(
                f"operand group axis {a.shape[0]} != spec.groups "
                f"{spec.groups}")
        a3, b3, mult3 = a, b, epilogue_mult
    stats.record(spec.stats_key)
    if spec.emits_bitmap:
        # The emitted bitmap is a gradient-side bitmap COMPUTATION (it
        # replaces the standalone scan_pallas:grad pass), so it counts
        # toward the one-computation-per-tensor-per-step budget.
        stats.record("emit:grad")
    if _GEMM_EVENTS is not None:
        _GEMM_EVENTS.append(spec)
    _observe_live_tiles(spec, a3, b3, masks)
    with stats.lifecycle_scope("gemm", f"{spec.schedule}:{spec.groups}"):
        res = _dispatch(a3, b3, masks, spec, mult3)
    if spec.emits_bitmap:
        out, bits = res
        bits = _tamper("gemm:emit_bits", bits)
        return (out[0], bits[0]) if not grouped_in else (out, bits)
    return res[0] if not grouped_in else res


def _observe_live_tiles(spec: GemmSpec, a3, b3, masks: GemmMasks) -> None:
    """Measured live-tile telemetry for the autotuner (kernels/autotune.py).

    Only CONCRETE masks are observed — an eager dispatch (the wall-clock
    harness, probe steps, eager grads' forward pass) yields real measured
    fractions; a traced dispatch carries tracers and records nothing, so
    the telemetry is never a modeled number.  Fractions are over the
    UNPADDED block bitmaps: the fraction of live output tiles (the compact
    queue's work units; 1.0 when no out mask) and the min live fraction
    across operand masks (the input-skipping signal)."""
    present = [m for m in masks if m is not None]
    if not present or any(isinstance(m, jax.core.Tracer) for m in present):
        return
    import numpy as np

    def frac(m) -> float:
        arr = np.asarray(m)
        return float(arr.astype(bool).mean()) if arr.size else 1.0

    out_frac = frac(masks.out) if masks.out is not None else 1.0
    operand = [frac(m) for m in (masks.a, masks.b) if m is not None]
    op_frac = min(operand) if operand else 1.0
    from . import autotune
    _, m, k = a3.shape
    autotune.observe_dispatch(spec, (m, k, b3.shape[2]), out_frac, op_frac)


def _dispatch(a, b, masks: GemmMasks, spec: GemmSpec, mult):
    """Pad → (queue →) launch → (scatter →) unpad.  Exists exactly once.

    Returns ``out`` (G, M, N) — or ``(out, bits)`` with the emitted
    (G, ⌈M/er⌉, ⌈N/ec⌉) bitmap when the spec stages ``bitmap_emit``.
    Every branch (dense, predicated, compact, overflow fallback) produces
    the same pytree structure, so the runtime ``lax.cond`` composes."""
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 == spec.groups and k == k2, (a.shape, b.shape, spec)
    bm, bk, bn = spec.block
    out_dtype = spec.out_dtype
    emit = spec.emit_gran if spec.emits_bitmap else None
    if mult is not None:
        assert mult.shape == (g, m, n), (mult.shape, (g, m, n))

    if spec.schedule == "dense":
        # Numerically-equivalent dense compute + masking: the skipped work
        # is accounted by core.costmodel, not saved on this backend.
        # Operand masks are metadata-only here (they feed the cost model).
        out = jnp.einsum("gmk,gkn->gmn", a.astype(jnp.float32),
                         b.astype(jnp.float32))
        if masks.out is not None:
            em = jax.vmap(lambda mk: ref.expand_block_mask(mk, bm, bn))(
                masks.out.astype(jnp.float32))
            out = out * em[:, :m, :n]
        if mult is not None:
            out = out * mult.astype(jnp.float32)
        if emit is None:
            return out.astype(out_dtype)
        er, ec = emit
        me, ne = ceil_to(m, er), ceil_to(n, ec)
        ob = jnp.abs(pad3(out, me, ne))
        bits = (jnp.max(ob.reshape(g, me // er, er, ne // ec, ec),
                        axis=(2, 4)) > 0).astype(jnp.int32)
        return out.astype(out_dtype), bits

    ni, nk, nj = grid_shape((m, k, n), spec.block)
    mp, kp, np_ = ni * bm, nk * bk, nj * bn
    a_p = pad3(a, mp, kp)
    b_p = pad3(b, kp, np_)
    mult_p = None if mult is None else pad3(mult.astype(jnp.float32), mp, np_)
    om = pad_mask3(masks.out, g, ni, nj)
    am = pad_mask3(masks.a, g, ni, nk)
    bmask = pad_mask3(masks.b, g, nk, nj)
    itp = _use_interpret(spec.interpret)

    def _predicated():
        return grouped_masked_matmul_kernel(
            a_p, b_p, om, am, bmask,
            bm=bm, bk=bk, bn=bn, out_dtype=out_dtype,
            epilogue_mult=mult_p, emit_gran=emit, interpret=itp,
        )

    if spec.schedule == "compact":
        s_cap = spec.max_active_blocks \
            if spec.max_active_blocks is not None else g * ni * nj
        # One queue over all groups: flatten (G, Mb, Nb) to (G·Mb, Nb) so
        # the row-major builder order IS lexicographic (g, i, j) — the WDU
        # dispatch order lifted to the group axis; decode the group
        # coordinate back out of the fused row index.
        fi, jj, n_live_v = build_queue(
            om.reshape(g * ni, nj), capacity=s_cap,
            builder=spec.queue_builder, interpret=itp)
        gg = fi // ni
        ii = fi % ni
        n_live = n_live_v[0]
        n_active = jnp.minimum(n_live, s_cap).reshape(1)

        def _compact():
            compacted = grouped_compact_masked_matmul_kernel(
                a_p, b_p, gg, ii, jj, n_active, am, bmask,
                bm=bm, bk=bk, bn=bn, out_dtype=out_dtype,
                epilogue_mult=mult_p, emit_gran=emit, interpret=itp,
            )
            if emit is not None:
                compacted, bits_c = compacted
            # Scatter the queue back to dense tile layout.  Padding steps
            # carry zero tiles at coords of dead queue slots — we direct
            # dead slots at (0, 0, 0) via scatter-ADD so they are no-ops.
            live_slot = jnp.arange(s_cap) < n_active[0]
            live = live_slot.astype(out_dtype)
            masked = compacted * live[:, None, None]
            sg = jnp.where(live_slot, gg, 0)
            si = jnp.where(live_slot, ii, 0)
            sj = jnp.where(live_slot, jj, 0)
            out_tiles = jnp.zeros((g, ni, nj, bm, bn), out_dtype)
            out_tiles = out_tiles.at[sg, si, sj].add(masked)
            out_d = out_tiles.transpose(0, 1, 3, 2, 4).reshape(g, mp, np_)
            if emit is None:
                return out_d
            # Emitted bits ride the same steered scatter as their tiles
            # (dead slots carry zero bits: their accumulator never left 0).
            er, ec = emit
            bits_m = bits_c * live_slot.astype(jnp.int32)[:, None, None]
            bt = jnp.zeros((g, ni, nj, bm // er, bn // ec), jnp.int32)
            bt = bt.at[sg, si, sj].add(bits_m)
            bits = bt.transpose(0, 1, 3, 2, 4).reshape(
                g, mp // er, np_ // ec)
            return out_d, bits

        if s_cap >= g * ni * nj:
            out = _compact()          # queue provably cannot overflow
        else:
            # Queue-capacity overflow would silently drop live tiles.  The
            # live count is a traced value, so detect at runtime and fall
            # back to the predicated (full-grid) schedule — exact always.
            # Both branches return the same (out[, bits]) pytree.
            if not isinstance(n_live, jax.core.Tracer) \
                    and int(n_live) > s_cap:
                # Concrete dispatch overflowed: count the fallback and
                # attribute it to the spec's autotune key so a persistently
                # overflowing spec can be demoted off the compact schedule
                # (kernels/autotune.py quarantine ladder).
                stats.record("fallback:queue_overflow")
                from . import autotune
                autotune.report_overflow(spec, (m, k, n))
            out = jax.lax.cond(n_live > s_cap, _predicated, _compact)
    else:
        out = _predicated()
    if emit is None:
        return out[:, :m, :n]
    er, ec = emit
    out, bits = out
    # Padding tiles are dead (zero accumulators), so the padded bitmap
    # rows/cols are exactly 0 — unpadding to the data's covering grid is
    # exact, matching what a fresh scan of the unpadded output would give.
    return out[:, :m, :n], bits[:, :ceil_to(m, er) // er,
                                :ceil_to(n, ec) // ec]


# ---------------------------------------------------------------------------
# Bitmap producers (encode/scan) — unchanged contract
# ---------------------------------------------------------------------------

def bitmap_scan(
    x: jnp.ndarray,
    *,
    block: Tuple[int, int] = (DEFAULT_BLOCK[0], DEFAULT_BLOCK[2]),
    kind: str = "act",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pallas block-any-nonzero bitmap of SIGNED data at granularity
    ``block`` — the encoder for tensors with no ReLU to fuse into (raw
    inputs, incoming gradients).  Pads, launches, unpads.

    Counted under the distinct ``scan_pallas:<kind>`` stats key so the
    audit can tell TPU-native scans from the retained XLA-reference scans
    (``scan:<kind>``); both still count toward the one-computation-per-
    tensor-per-step budget.
    """
    m, n = x.shape
    bm, bn = block
    lr = bm * max(1, -(-8 // bm))
    mp, np_ = ceil_to(m, lr), ceil_to(n, bn)
    stats.record(f"scan_pallas:{kind}")
    with stats.lifecycle_scope("scan", kind):
        x_p = pad_to(x, mp, np_)
        bitmap = bitmap_scan_kernel(x_p, bm=bm, bn=bn, lr=lr, lc=np_,
                                    interpret=_use_interpret(interpret))
        return bitmap[: ceil_to(m, bm) // bm, :]


def relu_encode(
    z: jnp.ndarray,
    *,
    block: Tuple[int, int] = (DEFAULT_BLOCK[0], DEFAULT_BLOCK[2]),
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused relu(z) + block bitmap at granularity ``block``.

    Pads, launches, unpads.  The launch tile is decoupled from the bitmap
    granularity (≥8 rows per grid step), so fine granularities — down to
    per-row bitmaps, which the conv path needs for im2col-derivable
    metadata — stay cheap to launch.

    This is THE forward-pass bitmap computation: one fused pass per
    activation per step; every downstream mask is derived from its result.
    """
    m, n = z.shape
    bm, bn = block
    # Launch slab: a multiple of the bitmap granularity covering >=8 rows.
    lr = bm * max(1, -(-8 // bm))
    mp, np_ = ceil_to(m, lr), ceil_to(n, bn)
    stats.record("encode:act")
    with stats.lifecycle_scope("encode", "act"):
        z_p = pad_to(z, mp, np_)
        y, bitmap = relu_encode_kernel(z_p, bm=bm, bn=bn, lr=lr, lc=np_,
                                       interpret=_use_interpret(interpret))
        return y[:m, :n], bitmap[: ceil_to(m, bm) // bm, :]


# ---------------------------------------------------------------------------
# The paper's composite ops, spec-driven
# ---------------------------------------------------------------------------

def relu_bwd_masked(
    dy: jnp.ndarray,          # (M, K) δ_post — gradient arriving from layer above
    w_t: jnp.ndarray,         # (K, N) Wᵀ of the producer layer
    relu_mask: jnp.ndarray,   # (M, N) {0,1} σ'(z) captured in the forward pass
    *,
    spec: Optional[GemmSpec] = None,
    use_input_sparsity: bool = True,
    use_output_sparsity: bool = True,
) -> jnp.ndarray:
    """δ_pre = (δ_post @ Wᵀ) ⊙ σ'(z) with block skipping — the paper's core op.

    OUTPUT sparsity: tiles where σ'(z) is all-zero are never computed.
    INPUT sparsity: K-tiles of δ_post that are all-zero are skipped.
    Partially-live tiles are computed densely then Hadamard-masked — exact
    (the σ′ multiply rides the kernel's fused epilogue).  ``spec`` carries
    tile shape / schedule / queue builder; its epilogue field is forced to
    ``sigma_prime`` since this op IS the fused-epilogue GEMM.
    """
    spec = GemmSpec() if spec is None else spec
    spec = spec.with_(epilogue="sigma_prime", groups=1)
    bm, bk, bn = spec.block
    mask32 = relu_mask.astype(jnp.float32)
    out_mask = block_bitmap(mask32, bm, bn) if use_output_sparsity else None
    a_mask = block_bitmap(dy.astype(jnp.float32), bm, bk) \
        if use_input_sparsity else None
    return sparse_gemm(dy, w_t, GemmMasks(out_mask, a_mask, None), spec,
                       epilogue_mult=mask32)


def weight_grad_masked(
    x_t: jnp.ndarray,        # (N, M) Xᵀ — activations (sparse post-ReLU)
    dy: jnp.ndarray,         # (N, K) δ — gradient (sparse post-ReLU-Hadamard)
    *,
    spec: Optional[GemmSpec] = None,
    use_input_sparsity: bool = True,
) -> jnp.ndarray:
    """dW = Xᵀ @ δ with INPUT sparsity on both operands (the paper's WG stage).

    There is no output sparsity in WG — every weight gradient entry is
    needed — but the contraction (batch·spatial) dimension tiles where
    either operand is all-zero are skipped.
    """
    spec = GemmSpec() if spec is None else spec
    spec = spec.with_(epilogue="none", groups=1)
    bm, bk, bn = spec.block
    a_mask = b_mask = None
    if use_input_sparsity:
        a_mask = block_bitmap(x_t.astype(jnp.float32), bm, bk)
        b_mask = block_bitmap(dy.astype(jnp.float32), bk, bn)
    return sparse_gemm(x_t, dy, GemmMasks(None, a_mask, b_mask), spec)
