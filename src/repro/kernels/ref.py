"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (exact block
semantics, not approximations): ``masked_matmul`` must be *bit-identical* to
masking the dense product, because the paper's skipping is lossless.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Block bitmap helpers (shared by oracle and host-side wrappers)
# ---------------------------------------------------------------------------

def block_any_nonzero(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """(M, N) -> (M//bm, N//bn) int32 bitmap; 1 where a block has any nonzero."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, bm, bn)
    xb = x.reshape(m // bm, bm, n // bn, bn)
    return (jnp.abs(xb).max(axis=(1, 3)) > 0).astype(jnp.int32)


def expand_block_mask(mask: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """(Mb, Nb) bitmap -> (Mb*bm, Nb*bn) elementwise {0,1} map."""
    return jnp.repeat(jnp.repeat(mask, bm, axis=0), bn, axis=1)


# ---------------------------------------------------------------------------
# masked_matmul oracle
# ---------------------------------------------------------------------------

def masked_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    out_mask: Optional[jnp.ndarray] = None,   # (M//bm, N//bn) int32/bool
    a_mask: Optional[jnp.ndarray] = None,     # (M//bm, K//bk)
    b_mask: Optional[jnp.ndarray] = None,     # (K//bk, N//bn)
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=jnp.float32,
    epilogue_mult: Optional[jnp.ndarray] = None,  # (M, N) fused Hadamard
) -> jnp.ndarray:
    """Oracle for the block-sparse GEMM.

    out[i, j] (block) = sum_k a[i, k] @ b[k, j]
        over k where a_mask[i, k] and b_mask[k, j] are both set,
        and only if out_mask[i, j] is set (else exact zeros).

    Implemented by zeroing the *operand blocks* the kernel would skip, then
    doing a dense matmul — which is exactly the arithmetic the kernel
    performs, so results must match to the bit (same accumulation order not
    required: we compare with allclose at dtype-appropriate tolerance, and
    bit-exactness holds for the masked-out entries which must be exactly 0).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if a_mask is not None:
        af = af * expand_block_mask(a_mask.astype(jnp.float32), bm, bk)
    if b_mask is not None:
        bf = bf * expand_block_mask(b_mask.astype(jnp.float32), bk, bn)
    out = af @ bf
    if out_mask is not None:
        # Skipped output blocks are exact zeros.
        out = out * expand_block_mask(out_mask.astype(jnp.float32), bm, bn)
    if epilogue_mult is not None:
        out = out * epilogue_mult.astype(jnp.float32)
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# grouped masked_matmul oracle — G independent masked GEMMs
# ---------------------------------------------------------------------------

def grouped_masked_matmul(
    a: jnp.ndarray,                              # (G, M, K)
    b: jnp.ndarray,                              # (G, K, N)
    out_mask: Optional[jnp.ndarray] = None,      # (G, M//bm, N//bn)
    a_mask: Optional[jnp.ndarray] = None,        # (G, M//bm, K//bk)
    b_mask: Optional[jnp.ndarray] = None,        # (G, K//bk, N//bn)
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=jnp.float32,
    epilogue_mult: Optional[jnp.ndarray] = None,  # (G, M, N)
) -> jnp.ndarray:
    """Oracle for the grouped block-sparse GEMM: per-group semantics are
    exactly ``masked_matmul``'s; groups never mix (the group-boundary
    contract of grouped/depthwise convs)."""
    g, m, k = a.shape

    def _expand3(mask, b0, b1):
        # expand_block_mask over the flattened group-major rows: groups stay
        # contiguous, so one 2-D expansion serves all G bitmaps.
        gg, r, c = mask.shape
        return expand_block_mask(
            mask.astype(jnp.float32).reshape(gg * r, c), b0, b1
        ).reshape(gg, r * b0, c * b1)

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if a_mask is not None:
        af = af * _expand3(a_mask, bm, bk)
    if b_mask is not None:
        bf = bf * _expand3(b_mask, bk, bn)
    out = jnp.einsum("gmk,gkn->gmn", af, bf)
    if out_mask is not None:
        out = out * _expand3(out_mask, bm, bn)
    if epilogue_mult is not None:
        out = out * epilogue_mult.astype(jnp.float32)
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# relu_encode oracle
# ---------------------------------------------------------------------------

def relu_encode(z: jnp.ndarray, *, bm: int, bn: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused forward ReLU + block-bitmap encode.

    Returns (relu(z), bitmap) where bitmap[i, j] == 1 iff block (i, j) of
    relu(z) contains at least one strictly positive element.  The bitmap is
    the WC-sparsity structure of §3/§4 of the paper, at MXU-block granularity.
    """
    y = jnp.maximum(z, jnp.zeros((), dtype=z.dtype))
    return y, block_any_nonzero(y, bm, bn)


# ---------------------------------------------------------------------------
# relu_bwd_masked oracle: the full δ_pre producer (GEMM + Hadamard) fused.
# ---------------------------------------------------------------------------

def relu_bwd_masked(
    dy: jnp.ndarray,           # (M, K) incoming gradient δ_post (already dense or sparse)
    w_t: jnp.ndarray,          # (K, N) transposed weight
    relu_mask: jnp.ndarray,    # (M, N) {0,1} — σ'(z) captured in the forward pass
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """δ_pre = (δ_post @ Wᵀ) ⊙ σ'(z), computed with output-sparsity skipping.

    The oracle is the plain dense expression; the kernel must match it
    exactly, because skipped blocks are exactly the all-zero blocks of
    σ'(z).
    """
    out = (dy.astype(jnp.float32) @ w_t.astype(jnp.float32)) * relu_mask.astype(jnp.float32)
    return out.astype(out_dtype)
