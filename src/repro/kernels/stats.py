"""Bitmap-op instrumentation: how many times per step is sparsity metadata
*computed* (a dense scan / fused encode over tensor-sized data), as opposed
to *derived* (coarsen / transpose / im2col on an existing bitmap)?

The paper's Encoder produces each layer's sparsity metadata exactly once per
pass and amortizes it over O(M·k²) reuse (§4.1).  The seed code instead
re-scanned activations up to three times per layer per step.  This counter
makes the difference auditable: ``benchmarks/kernel_audit.bitmap_op_audit``
asserts exactly ONE computation per activation per training step.

Counts are recorded at Python trace time, so one eager fwd+bwd (or one
trace of a jitted step) yields the per-step op count.  Derivations are
deliberately NOT recorded — they are pure bitmap arithmetic, the cheap
"free byproduct" reuse the paper is about.
"""
from __future__ import annotations

import collections
import contextlib
from typing import Dict

_COUNTS: "collections.Counter[str]" = collections.Counter()


def record(kind: str) -> None:
    """Register one bitmap *computation*.  ``kind`` is ``<how>:<what>``:
    how ∈ {encode, scan, queue} (fused-kernel vs standalone dense scan vs
    work-queue construction),
    what ∈ {act, grad} for encode/scan; for queue it is the builder backend
    ∈ {prefix_sum, argsort} — so ``total("argsort")`` audits that the
    default compact path never sorts (the PR-2 contract)."""
    _COUNTS[kind] += 1


def reset() -> None:
    _COUNTS.clear()


def counts() -> Dict[str, int]:
    return dict(_COUNTS)


def total(what: str = "") -> int:
    """Total computations, optionally filtered by the ``:<what>`` suffix."""
    return sum(v for k, v in _COUNTS.items()
               if not what or k.endswith(":" + what))


def queue_builds(builder: str = "") -> int:
    """Work-queue constructions, optionally for one builder backend.
    ``queue_builds("argsort") == 0`` is the no-sort-on-the-critical-path
    assertion for the default compact schedule."""
    return sum(v for k, v in _COUNTS.items()
               if k.startswith("queue:")
               and (not builder or k == "queue:" + builder))


@contextlib.contextmanager
def counting():
    """Scoped counter: resets on entry, yields the live ``counts`` getter."""
    reset()
    try:
        yield counts
    finally:
        pass
