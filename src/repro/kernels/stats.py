"""Bitmap-op instrumentation: how many times per step is sparsity metadata
*computed* (a dense scan / fused encode over tensor-sized data), as opposed
to *derived* (coarsen / transpose / im2col on an existing bitmap)?

The paper's Encoder produces each layer's sparsity metadata exactly once per
pass and amortizes it over O(M·k²) reuse (§4.1).  The seed code instead
re-scanned activations up to three times per layer per step.  This counter
makes the difference auditable: ``benchmarks/kernel_audit.bitmap_op_audit``
asserts exactly ONE computation per activation per training step.

Counts are recorded at Python trace time, so one eager fwd+bwd (or one
trace of a jitted step) yields the per-step op count.  Derivations are
deliberately NOT recorded — they are pure bitmap arithmetic, the cheap
"free byproduct" reuse the paper is about.

Key families (normalized):
  encode:act / scan:<what> / scan_pallas:<what>   bitmap computations
  queue:<builder>                                 work-queue constructions
  gemm:<schedule>:<g>                             one per sparse_gemm
                                                  dispatch (schedule ∈
                                                  {predicated, compact,
                                                  dense}; g = group count)
  conv:dense_fallback                             escaped-the-engine convs
  fallback:queue_overflow                         compact dispatches whose
                                                  live count exceeded the
                                                  queue capacity (concrete
                                                  dispatches only — traced
                                                  ones can't be counted)
  registry:hit / registry:miss                    grad-bitmap registry
                                                  lookups (a miss means a
                                                  consumer proceeds with no
                                                  dy mask — lost skipping,
                                                  never wrong numerics)
  guard:<event>                                   runtime guard layer
                                                  (docs/resilience.md):
                                                  nonfinite_skip,
                                                  bitmap_mismatch,
                                                  registry_miss, demote,
                                                  quarantine_clamp,
                                                  ckpt_fallback,
                                                  verdict:<v>

Legacy key heads from the pre-redesign orchestrators ("mm", "gmm",
"grouped_mm") are aliased onto the normalized ``gemm`` family at record
time, so old recorders and new readers agree.
"""
from __future__ import annotations

import collections
import contextlib
import itertools
from typing import Dict, Optional, Tuple

import jax

_COUNTS: "collections.Counter[str]" = collections.Counter()

# Pre-redesign per-GEMM key heads → the normalized family.  Aliasing happens
# at record() time so queries never need to know the legacy spellings.
_KEY_ALIASES = {"mm": "gemm", "gmm": "gemm", "grouped_mm": "gemm"}


def _normalize(kind: str) -> str:
    head, sep, rest = kind.partition(":")
    return _KEY_ALIASES.get(head, head) + sep + rest


def record(kind: str) -> None:
    """Register one counted event.  ``kind`` is ``<how>:<what>``:
    how ∈ {encode, scan, scan_pallas, queue, gemm} (fused-kernel vs
    standalone dense scan vs work-queue construction vs GEMM dispatch),
    what ∈ {act, grad} for encode/scan; for queue it is the builder backend
    ∈ {prefix_sum, argsort} — so ``queue_builds("argsort")`` audits that the
    default compact path never sorts (the PR-2 contract); for gemm it is
    ``<schedule>:<g>`` — the dispatcher's normalized launch key."""
    _COUNTS[_normalize(kind)] += 1


def reset() -> None:
    _COUNTS.clear()
    _LIVE.clear()


def counts() -> Dict[str, int]:
    return dict(_COUNTS)


def total(what: str = "") -> int:
    """Total computations, optionally filtered by the ``:<what>`` suffix."""
    return sum(v for k, v in _COUNTS.items()
               if not what or k.endswith(":" + what))


def queue_builds(builder: str = "") -> int:
    """Work-queue constructions, optionally for one builder backend.
    ``queue_builds("argsort") == 0`` is the no-sort-on-the-critical-path
    assertion for the default compact schedule."""
    return sum(v for k, v in _COUNTS.items()
               if k.startswith("queue:")
               and (not builder or k == "queue:" + builder))


def gemm_launches(schedule: str = "", groups: Optional[int] = None) -> int:
    """GEMM dispatches (``gemm:<schedule>:<g>``), optionally filtered by
    schedule and/or group count — the reader the kernel audits use for the
    normalized per-launch keys."""
    n = 0
    for k, v in _COUNTS.items():
        if not k.startswith("gemm:"):
            continue
        # Aliased legacy recorders may lack the :<g> suffix ("mm:compact"
        # → "gemm:compact"); treat the group field as unknown rather than
        # crashing the reader.
        _, _, tail = k.partition(":")
        sched, _, g = tail.partition(":")
        if schedule and sched != schedule:
            continue
        if groups is not None and (not g.isdigit() or int(g) != groups):
            continue
        n += v
    return n


def guard_counts() -> Dict[str, int]:
    """The ``guard:*`` family — the runtime guard layer's detection and
    verdict counters (docs/resilience.md)."""
    return {k: v for k, v in _COUNTS.items() if k.startswith("guard:")}


# Runtime (execution-time) counters ride on jax.debug.callback — a host
# round-trip per execution PER SHARD.  That is the right trade for audits
# and tests, but on a hot path being wall-clock benchmarked the callbacks
# dominate the thing measured; this trace-time switch lets a harness trace
# without them.  Default ON: correctness tooling never has to opt in.
_RUNTIME_COUNTING = True


def set_runtime_counting(on: bool) -> bool:
    """Enable/disable ``record_at_runtime`` callback staging at trace time;
    returns the previous setting (restore it in a finally)."""
    global _RUNTIME_COUNTING
    prev, _RUNTIME_COUNTING = _RUNTIME_COUNTING, bool(on)
    return prev


def record_at_runtime(kind: str, flag) -> None:
    """Increment counter ``kind`` at EXECUTION time by the runtime value of
    ``flag`` (a traced 0/1 scalar) — the escape hatch for events that only
    exist at run time, like the optimizer's non-finite skip.  ``record``
    counts at trace time (once per trace); this counts once per execution
    in which ``flag`` is nonzero, via an async host callback (it does not
    force a device sync on the value's consumers).  A no-op while
    ``set_runtime_counting(False)`` is in effect (benchmark harnesses)."""
    if not _RUNTIME_COUNTING:
        return
    import jax as _jax

    def _cb(v):
        if float(v) != 0.0:
            _COUNTS[_normalize(kind)] += 1

    _jax.debug.callback(_cb, flag)


@contextlib.contextmanager
def counting():
    """Scoped counter: resets on entry, yields the live ``counts`` getter."""
    reset()
    try:
        yield counts
    finally:
        pass


# ---------------------------------------------------------------------------
# Live-tile telemetry — the measured signal the spec-keyed autotuner tunes on
# ---------------------------------------------------------------------------
#
# The counters above say WHICH kernels launched; these buffers say how much
# of each launch was live.  Every concrete (non-traced) ``sparse_gemm``
# dispatch records its unpadded live-tile fractions under its autotune key
# (``kernels/autotune.key_for``): the fraction of live OUTPUT tiles (the
# compact queue's work units) and the min live fraction across operand
# masks (the input-skipping signal).  A bounded ring buffer per key keeps
# the trailing window of recent steps — what ``AutotuneCache.resolve``
# reads to pick a schedule, and what its drift re-evaluation compares
# against.  Traced dispatches carry tracers and record nothing: these are
# MEASURED fractions, never modeled ones.

LIVE_WINDOW = 128

_LIVE: Dict[str, "collections.deque[Tuple[float, float]]"] = {}


def record_live_tiles(key: str, out_frac: float,
                      operand_frac: float = 1.0) -> None:
    """Append one measured (out, operand) live-tile fraction pair for
    ``key`` (bounded: the newest ``LIVE_WINDOW`` samples are kept)."""
    buf = _LIVE.get(key)
    if buf is None:
        buf = _LIVE[key] = collections.deque(maxlen=LIVE_WINDOW)
    buf.append((float(out_frac), float(operand_frac)))


def live_tile_stats(key: str, window: Optional[int] = None
                    ) -> Tuple[Optional[float], Optional[float], int]:
    """(mean out-live fraction, mean operand-live fraction, n) over the
    trailing ``window`` samples for ``key`` — (None, None, 0) if nothing
    has been observed."""
    buf = _LIVE.get(key)
    if not buf:
        return None, None, 0
    items = list(buf)
    if window is not None:
        items = items[-window:]
    outs = sum(o for o, _ in items) / len(items)
    opnds = sum(p for _, p in items) / len(items)
    return outs, opnds, len(items)


def live_tile_keys() -> list:
    """Keys that have at least one recorded live-tile sample."""
    return [k for k, v in _LIVE.items() if v]


# ---------------------------------------------------------------------------
# Lifecycle scopes — the static (jaxpr-visible) twin of the counters above
# ---------------------------------------------------------------------------
#
# Counters audit a trace that RAN.  ``analysis/jaxpr_audit.py`` proves the
# same lifecycle contract on any program WITHOUT running it, by walking the
# jaxpr's ``eqn.source_info.name_stack``.  For that, every bitmap event must
# leave a machine-readable tag in the traced program, which ``jax.named_scope``
# provides: scope names survive tracing, jvp and transposition (they reappear
# wrapped as ``jvp(tag)`` / ``transpose(jvp(tag))``).
#
# Tag grammar (parsed by analysis.jaxpr_audit.parse_tag):
#
#     repro:<kind>[:<detail>]:<seq>
#
# kind ∈ {encode, scan, derive, queue, gemm, fallback} — mirroring the
# counter families.  <seq> is a process-global instance number so two scans
# of the SAME tensor get DISTINCT region identities (that duplication is
# exactly the violation the audit must be able to see).  Model layers use
# the separate ``layer:<name>`` grammar (``layer_scope``) purely for keying
# violation reports by layer.

_SCOPE_SEQ = itertools.count()


def lifecycle_scope(kind: str, detail: str = ""):
    """A ``jax.named_scope`` carrying one bitmap-lifecycle event tag.

    Wrap the ops that *compute* sparsity metadata (kind="encode"/"scan"),
    *derive* it (kind="derive"), build work queues (kind="queue"), consume
    it in a GEMM dispatch (kind="gemm"), or escape the engine entirely
    (kind="fallback").  ``detail`` refines the kind (e.g. the scan target,
    the gemm ``<schedule>:<g>`` launch key).
    """
    parts = ["repro", kind] + ([detail] if detail else []) \
        + [str(next(_SCOPE_SEQ))]
    return jax.named_scope(":".join(parts))


def layer_scope(name: str):
    """A ``jax.named_scope`` keying everything under it to one model layer —
    the audit uses it only to label violations (``layer:<name>``)."""
    return jax.named_scope(f"layer:{name}")
