"""Shared shape/padding/bitmap helpers for the masked-GEMM stack.

The old 2-D/grouped orchestrator split left near-identical private copies of
these scattered across ``kernels/ops.py`` (``_ceil_to``/``_pad_to``/
``_pad3``/``_pad_mask``/``_block_bitmap``), ``core/policy.py`` (a second
``_ceil_to``) and ``core/sparse_linear.py`` (the padded-scan oracle).  This
module is their single home; everything here is pure shape arithmetic with
zero policy or kernel knowledge, so any layer may import it.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from . import ref


def ceil_to(v: int, b: int) -> int:
    """Round ``v`` up to the next multiple of ``b``."""
    return -(-v // b) * b


def pad_to(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to (m, n) on the trailing edges."""
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def pad3(x: jnp.ndarray, d1: int, d2: int) -> jnp.ndarray:
    """Zero-pad a (G, ·, ·) array up to (G, d1, d2) on the trailing edges —
    the grouped form of ``pad_to`` (the leading group axis is never padded)."""
    p1, p2 = d1 - x.shape[1], d2 - x.shape[2]
    if p1 == 0 and p2 == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, p1), (0, p2)))


def ones_bitmap(nb0: int, nb1: int) -> jnp.ndarray:
    """All-live (nb0, nb1) tile bitmap — the ``mask=None`` (dense) meaning."""
    return jnp.ones((nb0, nb1), jnp.int32)


def pad_mask(mask: Optional[jnp.ndarray], nb0: int, nb1: int) -> jnp.ndarray:
    """A (≤nb0, ≤nb1) tile bitmap zero-padded to (nb0, nb1); ``None`` means
    dense ⇒ all-ones.  Padded tiles describe padded (all-zero) data, so the
    zero fill is the exact bitmap of that data."""
    if mask is None:
        return ones_bitmap(nb0, nb1)
    mask = mask.astype(jnp.int32)
    p0, p1 = nb0 - mask.shape[0], nb1 - mask.shape[1]
    if p0 or p1:
        mask = jnp.pad(mask, ((0, p0), (0, p1)))
    return mask


def pad_mask3(mask: Optional[jnp.ndarray], g: int, nb0: int,
              nb1: int) -> jnp.ndarray:
    """Grouped form of ``pad_mask``: (G, ≤nb0, ≤nb1) → (G, nb0, nb1)."""
    if mask is None:
        return jnp.ones((g, nb0, nb1), jnp.int32)
    return pad3(mask.astype(jnp.int32), nb0, nb1)


def block_bitmap(x: jnp.ndarray, b0: int, b1: int) -> jnp.ndarray:
    """Any-nonzero block bitmap of a 2-D array at tile (b0, b1), zero-padding
    ragged edges first (padding is dead data, so its bits are 0).  This is
    the one dense-scan primitive shared by the kernel wrappers and the
    threading tests' freshly-scanned oracle."""
    m, n = x.shape
    return ref.block_any_nonzero(pad_to(x, ceil_to(m, b0), ceil_to(n, b1)),
                                 b0, b1)


def grid_shape(dims: Tuple[int, ...], block: Tuple[int, ...]) -> Tuple[int, ...]:
    """Per-axis tile counts: ceil(dim / edge) for each (dim, edge) pair."""
    assert len(dims) == len(block), (dims, block)
    return tuple(ceil_to(d, e) // e for d, e in zip(dims, block))
