"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf].

27L d_model=2048 16H (GQA kv=16, via MLA) d_ff=1408 (routed expert)
vocab=102400.  Layer 0 is a dense-FFN layer (d_ff 10944); layers 1–26 MoE.
MLA is still full softmax attention over the sequence → long_500k skipped.
"""
from repro.models.moe import MoEConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                   # dense prefix layer FFN
    vocab_size=102400,
    ffn_activation="silu_glu",
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, d_ff_shared=2816,
                  activation="silu_glu"),
    moe_every=1,
    n_dense_layers=1,
    tie_embeddings=False,
)

SMOKE = CONFIG.with_(
    n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, d_ff=192,
    vocab_size=512, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                  n_shared_experts=1, d_ff_shared=64,
                  activation="silu_glu"))
