"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period of 8: one attention layer (position 4) per 7 Mamba layers; MoE on
every second layer.  Hybrid (7/8 recurrent) → runs long_500k (the single
attention layer per period carries a full 500k KV cache, SP-sharded).
"""
from repro.models.moe import MoEConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern="MMMMAMMM",
    ffn_activation="silu_glu",
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                  activation="silu_glu"),
    moe_every=2,
    moe_offset=1,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    tie_embeddings=False,
)

SMOKE = CONFIG.with_(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, ssm_chunk=8,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                  activation="silu_glu"))
