"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b family; unverified].

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    ffn_activation="silu_glu",
    tie_embeddings=False,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                     d_ff=128, vocab_size=512)
