"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
MoE on every layer.  Pure full attention → long_500k skipped.
"""
from repro.models.moe import MoEConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    ffn_activation="gelu_glu",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768,
                  activation="gelu_glu"),
    moe_every=1,
    tie_embeddings=False,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                  activation="gelu_glu"))
