"""internvl2-1b [vlm] — InternViT + InternLM2/Qwen2-0.5B backbone
[arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The ViT frontend
is a STUB per the brief: input_specs() provides precomputed patch
embeddings (projected in-model to d_model).  Pure full attention →
long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    ffn_activation="silu_glu",
    frontend="vit",
    frontend_dim=1024,
    frontend_len=256,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                     d_ff=128, vocab_size=512, frontend_dim=32,
                     frontend_len=8)
