"""Config dataclasses shared by every architecture.

``ModelConfig`` is a superset covering dense / MoE / SSM-hybrid / xLSTM /
VLM / audio enc-dec families.  Layer structure is a *pattern* string (one
char per layer within a repeating period):

  A  global attention + FFN           L  sliding-window attention + FFN
  G  global attention + FFN (alias of A, used in local:global patterns)
  M  Mamba SSM block (+FFN)           m  mLSTM block        s  sLSTM block

The stack is ``pattern`` repeated ``n_layers // len(pattern)`` times (after
``n_dense_layers`` unrolled prefix layers), which is what lets the LM
assembly scan over periods with stacked params.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.xlstm import XLSTMConfig
from repro.models.attention import AttnConfig
from repro.models.ffn import FFNConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    ffn_activation: str = "silu_glu"
    norm: str = "rmsnorm"
    rope_theta: float = 10000.0
    pattern: str = "A"
    sliding_window: Optional[int] = None
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    moe_every: int = 1                # MoE on positions p with p % every == off
    moe_offset: int = 0
    n_dense_layers: int = 0           # unrolled dense-FFN prefix (deepseek)
    # --- MLA ---
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- SSM / xLSTM ---
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    xlstm_chunk: int = 64
    # --- encoder-decoder ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_pattern: str = "A"
    # --- modality frontend stubs ---
    frontend: Optional[str] = None    # "vit" | "audio"
    frontend_dim: int = 0             # stub embedding dim (projected in-model)
    frontend_len: int = 256           # number of patch/frame embeddings
    # --- misc ---
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # beyond-paper: route ReLU-family FFNs through the sparse-bwd kernels
    sparse_ffn_scenario: Optional[str] = None   # "IN"|"IN_OUT"|"IN_OUT_WR"
    # attention lowering
    q_chunk: int = 512
    kv_chunk: int = 512
    attn_schedule: str = "rect"       # "rect" | "tri" (perf-optimized)
    remat: bool = True
    # scan unrolling (1 = rolled while-loops).  Used by the cost-model
    # validation tests: XLA's HLO cost analysis does not multiply while
    # bodies by trip count, so HLO-vs-analytic comparisons unroll.
    scan_unroll: int = 1

    # -- derived --
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_config(self, *, window: Optional[int] = None) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim_,
            rope_theta=self.rope_theta, window=window,
            use_mla=self.use_mla, kv_lora_rank=self.kv_lora_rank,
            qk_nope_dim=self.qk_nope_dim, qk_rope_dim=self.qk_rope_dim,
            v_head_dim=self.v_head_dim,
            q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
            schedule=self.attn_schedule, unroll=self.scan_unroll,
        )

    def ffn_config(self) -> FFNConfig:
        from repro.core.policy import SCENARIOS
        pol = SCENARIOS.get(self.sparse_ffn_scenario) \
            if self.sparse_ffn_scenario else None
        return FFNConfig(self.d_model, self.d_ff, self.ffn_activation,
                         sparse_policy=pol)

    def ssm_config(self) -> SSMConfig:
        return SSMConfig(d_model=self.d_model, d_state=self.ssm_d_state,
                         d_conv=self.ssm_d_conv, expand=self.ssm_expand,
                         chunk=self.ssm_chunk, unroll=self.scan_unroll)

    def xlstm_config(self) -> XLSTMConfig:
        return XLSTMConfig(d_model=self.d_model, n_heads=self.n_heads,
                           chunk=self.xlstm_chunk, unroll=self.scan_unroll)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind string for the decoder stack."""
        body = self.n_layers - self.n_dense_layers
        period = len(self.pattern)
        assert body % period == 0, (self.name, body, period)
        return tuple("A" * self.n_dense_layers + self.pattern * (body // period))

    def layer_uses_moe(self, idx: int) -> bool:
        if self.moe is None or idx < self.n_dense_layers:
            return False
        return (idx - self.n_dense_layers) % self.moe_every == self.moe_offset

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One cell of the (arch × shape) grid."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1              # grad-accum splits of the global batch
    loss_scale: float = 0.0            # 0 → off (bf16); >0 → fp16 static scale
    grad_compression: bool = False     # int8 error-feedback DP compression
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    seed: int = 0
