"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
are self-contained (mLSTM carries a 2× up-projection, sLSTM a 4/3 FFN);
there is no separate transformer FFN.  Pattern alternates mLSTM/sLSTM.
Recurrent O(1) state → runs the long_500k decode cell.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern="ms",
    ffn_activation="gelu",
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                     vocab_size=512, xlstm_chunk=8)
