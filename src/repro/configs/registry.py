"""Architecture registry, per-cell applicability, and input_specs().

``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct stand-ins for
every input of the lowered step function — weak-type-correct, shardable,
never allocating device memory — so the 512-device dry-run can
``.lower().compile()`` full-size cells on one CPU.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import (deepseek_v2_lite, gemma3_12b, grok1_314b, internvl2_1b,
               jamba_1_5_large, seamless_m4t_medium, smollm_360m,
               stablelm_1_6b, stablelm_3b, xlstm_350m)
from .base import ALL_SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "xlstm-350m": xlstm_350m,
    "stablelm-1.6b": stablelm_1_6b,
    "stablelm-3b": stablelm_3b,
    "smollm-360m": smollm_360m,
    "gemma3-12b": gemma3_12b,
    "grok-1-314b": grok1_314b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "jamba-1.5-large-398b": jamba_1_5_large,
    "internvl2-1b": internvl2_1b,
    "seamless-m4t-medium": seamless_m4t_medium,
}

ARCHS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKE_ARCHS: Dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}

# Archs whose sequence mixing is sub-quadratic (SSM / hybrid / mostly-local):
# these run the long_500k decode cell.  Pure full-attention archs skip it
# (recorded in DESIGN.md §Arch-applicability).
LONG_CONTEXT_OK = ("xlstm-350m", "jamba-1.5-large-398b", "gemma3-12b")


def get(name: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_ARCHS if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


def runs_cell(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: 500k decode cell skipped"
    return True, ""


def all_cells(include_skipped: bool = False):
    for name, cfg in ARCHS.items():
        for shape in ALL_SHAPES:
            ok, why = runs_cell(cfg, shape)
            if ok or include_skipped:
                yield name, cfg, shape, ok, why


# ---------------------------------------------------------------------------
# input_specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Batch pytree for lm_loss."""
    b, t = shape.global_batch, shape.seq_len
    act_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.enc_dec:
        frames = t // 2
        return {
            "tokens": _sds((b, t - frames + 1), jnp.int32),
            "frontend_embeds": _sds((b, frames, cfg.frontend_dim), act_dtype),
        }
    if cfg.frontend:
        f = cfg.frontend_len
        return {
            "tokens": _sds((b, t - f + 1), jnp.int32),
            "frontend_embeds": _sds((b, f, cfg.frontend_dim), act_dtype),
        }
    return {"tokens": _sds((b, t + 1), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       memory_len: int = 4096) -> Dict:
    """Inputs for decode_step: token, caches (KV of seq_len), index[, memory]."""
    from repro.models.transformer import init_caches
    b, t = shape.global_batch, shape.seq_len
    act_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    caches = jax.eval_shape(
        lambda: init_caches(cfg, b, t, act_dtype))
    spec = {
        "token": _sds((b,), jnp.int32),
        "caches": caches,
        "index": _sds((), jnp.int32),
    }
    if cfg.enc_dec:
        spec["memory"] = _sds((b, memory_len, cfg.d_model), act_dtype)
    return spec


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    if shape.kind == "train" or shape.kind == "prefill":
        return train_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
