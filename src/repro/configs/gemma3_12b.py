"""gemma3-12b [dense] — 5:1 local:global, 128k ctx [hf:google/gemma-3-1b-pt;
unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.  Pattern: five
sliding-window (1024) layers per global layer.  Not pure full-attention,
so the long_500k decode cell runs (window caches on L layers, full KV on
the 8 G layers).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    pattern="LLLLLG",
    sliding_window=1024,
    ffn_activation="gelu_glu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab_size=512, sliding_window=8)
