"""Architecture configs: one module per assigned arch + the paper's CNNs."""
from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   ModelConfig, ShapeConfig, TrainConfig)  # noqa: F401
from .registry import (ARCHS, LONG_CONTEXT_OK, SMOKE_ARCHS, all_cells, get,
                       input_specs, runs_cell)  # noqa: F401
