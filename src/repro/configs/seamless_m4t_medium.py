"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

12L (encoder) + 12L (decoder), d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206.  The speech frontend is a STUB: input_specs() provides
precomputed frame embeddings consumed by the encoder.  Standard
Transformer **ReLU** FFN → the paper's gradient-output-sparsity technique
applies NATIVELY to this arch (sparse_ffn_scenario can be enabled without
changing the architecture).  Full attention → long_500k skipped; decode
runs on the decoder (enc-dec, not encoder-only).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    ffn_activation="relu",
    norm="layernorm",
    enc_dec=True,
    n_enc_layers=12,
    frontend="audio",
    frontend_dim=1024,
    frontend_len=1024,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=4, d_ff=128, vocab_size=512,
                     frontend_dim=32, frontend_len=8)
