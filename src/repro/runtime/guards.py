"""StepGuard — per-step health folded into a verdict, and the degradation
ladder that acts on it (docs/resilience.md).

Signals folded per step:

  * non-finite loss / grad-norm / the optimizer's ``skipped`` flag (the
    fp16-overflow guard in ``optim.adamw_update``),
  * counter deltas from the kernel layer — ``fallback:queue_overflow``
    (compact queue overflows) and ``registry:miss`` (grad-bitmap hand-offs
    that never arrived),
  * on demand, an emitted-bitmap/value consistency probe (``probe_emit``).

Verdict ladder (each verdict is counted under ``guard:verdict:<v>``):

  ok        step was healthy; cooldown toward forgetting past rollbacks.
  skip      non-finite step, within the consecutive-skip budget — the
            optimizer already dropped the update (master weights regenerate
            the params), nothing else to do.
  rollback  the skip budget is exhausted: corruption persists across steps
            (it lives in optimizer/master state, not in one bad batch).
            The train loop restores the newest intact checkpoint.  Each
            rollback doubles the clean-step cooldown before the rollback
            counter resets (backoff).
  degrade   rollbacks are not converging either: demote every suspect
            ``AutotuneKey`` one rung down the degradation ladder
            (compact → predicated → dense, ``kernels/autotune.py``) — the
            assumption-heavy schedules are retired before numerics are.

The guard is HOST-side and opt-in: ``train_loop(guard=...)`` syncs the
small metric scalars each step only when a guard is installed, preserving
the PR-7 no-per-step-sync contract for unguarded runs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.kernels import autotune, stats

VERDICTS = ("ok", "skip", "rollback", "degrade")

# Raw counter families the guard scans for deltas between steps.
_SCANNED_COUNTERS = ("fallback:queue_overflow", "registry:miss")


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    max_consecutive_skips: int = 3   # non-finite steps tolerated in a row
                                     # before escalating to rollback
    rollback_backoff: int = 8        # clean steps after a rollback before
                                     # the hot-rollback counter cools;
                                     # doubles with each further rollback
    max_rollbacks: int = 2           # hot rollbacks before the next
                                     # escalation becomes degrade
    registry_miss_budget: int = 0    # registry misses per scan ABOVE the
                                     # caller's expected count (the loss
                                     # cotangent's structural miss) before
                                     # guard:registry_miss fires
    history: int = 1024              # verdict history kept for inspection


class StepGuard:
    """Folds per-step health into ``ok | skip | rollback | degrade``.

    State machine: consecutive non-finite steps consume the skip budget;
    exhausting it escalates to rollback (the loop restores a checkpoint and
    the budget restarts); ``max_rollbacks`` rollbacks without an intervening
    cooldown of clean steps escalate to degrade (suspect specs are demoted
    down the schedule ladder).  Clean steps cool the machine back down.
    """

    def __init__(self, config: Optional[GuardConfig] = None):
        self.config = config or GuardConfig()
        self.verdicts: List[Tuple[int, str]] = []
        self._consecutive_skips = 0
        self._rollbacks_hot = 0
        self._cooldown = 0
        self._counter_base: Dict[str, int] = {}

    # -- per-step fold ---------------------------------------------------

    def observe_step(self, step: int, *, loss: Optional[float] = None,
                     grad_norm: Optional[float] = None,
                     skipped: Optional[float] = None) -> str:
        """One training step's health → verdict.  ``skipped`` is the
        optimizer's non-finite-skip flag (nonzero = the update was
        dropped); loss/grad_norm are host floats, either may be None."""
        cfg = self.config
        nonfinite = bool(skipped) \
            or (loss is not None and not math.isfinite(loss)) \
            or (grad_norm is not None and not math.isfinite(grad_norm))
        if nonfinite:
            self._consecutive_skips += 1
            if self._consecutive_skips <= cfg.max_consecutive_skips:
                verdict = "skip"
            elif self._rollbacks_hot >= cfg.max_rollbacks:
                verdict = "degrade"
            else:
                verdict = "rollback"
        else:
            verdict = "ok"
            self._consecutive_skips = 0
            if self._cooldown > 0:
                self._cooldown -= 1
                if self._cooldown == 0:
                    self._rollbacks_hot = 0
        if verdict == "rollback":
            self._rollbacks_hot += 1
            self._consecutive_skips = 0       # budget restarts post-restore
            self._cooldown = cfg.rollback_backoff * 2 ** (
                self._rollbacks_hot - 1)
        elif verdict == "degrade":
            self._consecutive_skips = 0
        stats.record("guard:verdict:" + verdict)
        self.verdicts.append((int(step), verdict))
        if len(self.verdicts) > cfg.history:
            del self.verdicts[:-cfg.history]
        return verdict

    # -- counter scanning ------------------------------------------------

    def scan_counters(self, *, expected_registry_misses: int = 0
                      ) -> Dict[str, int]:
        """Deltas of the guard-relevant raw counters since the last scan.

        ``expected_registry_misses`` is the caller's structural baseline
        (e.g. one loss-cotangent miss per backward in the scanned span);
        misses beyond it plus the configured budget count as a detection
        (``guard:registry_miss``) — the registry-drop fault class."""
        now = stats.counts()
        deltas: Dict[str, int] = {}
        for key in _SCANNED_COUNTERS:
            cur = now.get(key, 0)
            deltas[key] = cur - self._counter_base.get(key, 0)
            self._counter_base[key] = cur
        excess = deltas.get("registry:miss", 0) \
            - expected_registry_misses - self.config.registry_miss_budget
        if excess > 0:
            stats.record("guard:registry_miss")
        return deltas

    # -- bitmap consistency probe ----------------------------------------

    def probe_emit(self, out, bits, gran: Tuple[int, int], *,
                   spec=None, dims=None):
        """Check an emitted (output, bitmap) pair for consistency: the
        bitmap must equal a fresh any-nonzero scan of ``out`` at ``gran``.

        A mismatch (bit flips in transit, a writeback that lied) records
        ``guard:bitmap_mismatch`` and — when the producing ``spec`` is
        given — tallies it as a suspect with the autotuner, feeding the
        degrade verdict.  Returns ``(ok, corrected_bits)``: consumers can
        continue with the rescanned (trusted) bitmap, so a flipped bit
        degrades to extra/lost skipping, never to wrong numerics."""
        ref = reference_bitmap(np.asarray(out), gran)
        got = np.asarray(bits)
        ok = got.shape == ref.shape and bool(np.array_equal(ref, got))
        if not ok:
            stats.record("guard:bitmap_mismatch")
            if spec is not None:
                autotune.get_cache().report_suspect(spec, dims, "bitmap")
        import jax.numpy as jnp
        return ok, jnp.asarray(ref, dtype=np.asarray(bits).dtype)

    # -- the degrade action ----------------------------------------------

    def degrade(self, *, reason: str = "guard"):
        """Demote every suspect key one rung down the degradation ladder;
        returns the demoted keys (``AutotuneCache.demote_suspects``)."""
        return autotune.get_cache().demote_suspects(reason=reason)

    # -- persistence (checkpoint state.json) ------------------------------

    def export_state(self) -> dict:
        return {
            "consecutive_skips": self._consecutive_skips,
            "rollbacks_hot": self._rollbacks_hot,
            "cooldown": self._cooldown,
            "counter_base": dict(self._counter_base),
            "verdicts": [[s, v] for s, v in self.verdicts[-64:]],
        }

    def import_state(self, doc: dict) -> None:
        self._consecutive_skips = int(doc.get("consecutive_skips", 0))
        self._rollbacks_hot = int(doc.get("rollbacks_hot", 0))
        self._cooldown = int(doc.get("cooldown", 0))
        self._counter_base = {k: int(v) for k, v in
                              doc.get("counter_base", {}).items()}
        self.verdicts = [(int(s), str(v))
                         for s, v in doc.get("verdicts", [])]


def reference_bitmap(out: np.ndarray, gran: Tuple[int, int]) -> np.ndarray:
    """Ground-truth any-nonzero tile bitmap of a (possibly grouped) output
    at granularity ``gran`` — the probe's oracle, matching the unpadding
    contract of ``sparse_gemm``'s emit path (padding tiles are dead)."""
    er, ec = gran
    arr = np.asarray(out)
    if arr.ndim == 2:
        return reference_bitmap(arr[None], gran)[0]
    if arr.ndim != 3:
        raise ValueError(f"expected 2-D or 3-D output, got {arr.shape}")
    g, m, n = arr.shape
    mt, nt = -(-m // er), -(-n // ec)
    padded = np.zeros((g, mt * er, nt * ec), dtype=arr.dtype)
    padded[:, :m, :n] = arr
    tiles = padded.reshape(g, mt, er, nt, ec)
    return (np.abs(tiles).max(axis=(2, 4)) > 0).astype(np.int32)
