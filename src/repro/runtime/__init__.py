"""Guarded execution (docs/resilience.md): per-step health verdicts, a
skip → rollback → degrade ladder, and the deterministic fault-injection
harness that proves every fault class is detected, attributed and
survived.

  * ``runtime.guards``  — ``StepGuard``: folds per-step health signals
    (non-finite loss/grad-norm, overflow-fallback and registry-miss
    counters, bitmap-consistency probes) into a verdict
    ``ok | skip | rollback | degrade``, recorded under ``guard:*`` stats
    keys and acted on by ``launch.train.train_loop``.
  * ``runtime.faults``  — seeded fault injection addressable by site, and
    the chaos matrix (``python -m repro.runtime.faults --matrix``).
"""
from .guards import GuardConfig, StepGuard, VERDICTS  # noqa: F401
