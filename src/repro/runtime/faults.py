"""Seeded, deterministic fault injection — and the chaos matrix that proves
each fault class is DETECTED (the intended guard fires), ATTRIBUTED (the
right ``guard:*`` / decision-log entry names it) and SURVIVED (training or
the artifact stays within tolerance of the un-faulted run).

Faults are addressed by SITE.  Sites are instrumented as hooks at the layer
that owns them — the kernels/core/checkpoint layers never import this
module; this module installs into their ``set_*_hook`` slots:

  train:params          NaN/Inf planted in the params pytree before one
                        step (corrupt activations → non-finite loss; heals
                        in one step: the optimizer's master weights
                        regenerate the params after the skipped update)
  train:opt_state       NaN/Inf planted in optimizer state (PERSISTENT
                        corruption: every later step is non-finite until a
                        rollback restores an intact checkpoint)
  gemm:spec             compact-queue capacity shrunk at dispatch
                        (``max_active_blocks``) — forces queue overflow
  gemm:emit_bits        bit flipped in an emitted dy bitmap
  registry:register     grad-bitmap registrations dropped (the hand-off
                        fault: emitted bitmaps never reach consumers)
  collective:allreduce  one shard's live-block contribution zeroed inside
                        the bitmap-compressed gradient all-reduce (the
                        transport-corruption class; the dense paths sit
                        outside the tamper point)
  checkpoint:post_leaves / checkpoint:pre_commit
                        the checkpoint writer crashes at that protocol
                        point (``InjectedCrash``)

``python -m repro.runtime.faults --matrix`` runs the whole catalogue (the
CI ``chaos`` job adds ``--fail-on-undetected`` and archives the CSV).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Callable, Dict, List, Optional

from repro.kernels import autotune, stats

# site → the fault kinds that make sense there (validated at arm time).
SITES: Dict[str, tuple] = {
    "train:params": ("nan", "inf"),
    "train:opt_state": ("nan", "inf"),
    "gemm:spec": ("queue_overflow",),
    "gemm:emit_bits": ("bitmap_flip",),
    "registry:register": ("registry_drop",),
    "collective:allreduce": ("drop_contrib",),
    "checkpoint:post_leaves": ("crash",),
    "checkpoint:pre_commit": ("crash",),
}


class InjectedCrash(RuntimeError):
    """Raised by an armed ``crash`` fault at its checkpoint protocol point —
    stands in for the writer process dying there."""


@dataclasses.dataclass
class Fault:
    """One armed fault.  ``step`` gates the stepped sites (``train:*``) to
    a single training step; ``seed`` makes the corrupted element/bit/
    capacity deterministic.  ``fired`` counts injections."""
    site: str
    kind: str
    step: Optional[int] = None
    seed: int = 0
    fired: int = 0


_ARMED: Dict[str, Fault] = {}
_PREV_HOOKS: Optional[tuple] = None


def arm(fault: Fault) -> Fault:
    """Arm ``fault`` at its site (replacing any fault already there) and
    install the layer hooks on first use."""
    if fault.site not in SITES:
        raise ValueError(f"unknown fault site {fault.site!r}; "
                         f"one of {sorted(SITES)}")
    if fault.kind not in SITES[fault.site]:
        raise ValueError(f"fault kind {fault.kind!r} not valid at "
                         f"{fault.site!r} (allowed: {SITES[fault.site]})")
    _ARMED[fault.site] = fault
    _install_hooks()
    return fault


def disarm(site: Optional[str] = None) -> None:
    """Disarm one site (or, with None, everything) and restore the layers'
    previous hooks once nothing is armed."""
    if site is None:
        _ARMED.clear()
    else:
        _ARMED.pop(site, None)
    if not _ARMED:
        _uninstall_hooks()


def active() -> Dict[str, Fault]:
    return dict(_ARMED)


def _install_hooks() -> None:
    global _PREV_HOOKS
    if _PREV_HOOKS is not None:
        return
    from repro import checkpoint as ckpt
    from repro.core import sparse_tensor
    from repro.kernels import ops
    from repro.sharding import collectives
    _PREV_HOOKS = (
        ops.set_tamper_hook(_tamper_hook),
        sparse_tensor.set_register_hook(_register_hook),
        ckpt.set_crash_hook(_crash_hook),
        collectives.set_collective_hook(_collective_hook),
    )


def _uninstall_hooks() -> None:
    global _PREV_HOOKS
    if _PREV_HOOKS is None:
        return
    from repro import checkpoint as ckpt
    from repro.core import sparse_tensor
    from repro.kernels import ops
    from repro.sharding import collectives
    tamper, register, crash, collective = _PREV_HOOKS
    ops.set_tamper_hook(tamper)
    sparse_tensor.set_register_hook(register)
    ckpt.set_crash_hook(crash)
    collectives.set_collective_hook(collective)
    _PREV_HOOKS = None


# ---------------------------------------------------------------------------
# The injections
# ---------------------------------------------------------------------------

def _tamper_hook(site: str, value):
    f = _ARMED.get(site)
    if f is None:
        return value
    if site == "gemm:spec" and f.kind == "queue_overflow":
        if value.schedule != "compact":
            return value          # nothing to overflow on other schedules
        f.fired += 1
        return value.with_(max_active_blocks=1 + f.seed % 2)
    if site == "gemm:emit_bits" and f.kind == "bitmap_flip":
        f.fired += 1
        return _flip_bit(value, f.seed)
    return value


def _flip_bit(bits, seed: int):
    import jax.numpy as jnp
    flat = jnp.reshape(bits, (-1,))
    idx = seed % flat.shape[0]
    flat = flat.at[idx].set(1 - flat[idx])
    return jnp.reshape(flat, bits.shape)


def _register_hook(obj, bitmap, gran):
    f = _ARMED.get("registry:register")
    if f is not None and f.kind == "registry_drop":
        f.fired += 1
        return False              # veto: the hand-off never happens
    return True


def _collective_hook(site: str, contrib, axis_name):
    """Zero the compact-buffer contribution of ONE shard (``seed`` picks
    which, mod the axis size) inside the compressed all-reduce — the
    collective analogue of a torn write: blocks only that shard owned
    arrive as zeros while the psum'd union bitmap still marks them live.
    ``fired`` counts traces, not executions (jit caches the traced
    tamper)."""
    f = _ARMED.get(site)
    if f is None or f.kind != "drop_contrib":
        return contrib
    import jax.numpy as jnp
    from jax import lax
    f.fired += 1
    name = axis_name if isinstance(axis_name, str) else axis_name[0]
    idx = lax.axis_index(name)
    n = lax.psum(1, name)
    keep = (idx != jnp.mod(f.seed, n)).astype(contrib.dtype)
    return contrib * keep


def _crash_hook(name: str) -> None:
    f = _ARMED.get(name)
    if f is not None and f.kind == "crash":
        f.fired += 1
        raise InjectedCrash(name)


def tap(site: str, value, *, step: Optional[int] = None):
    """Train-loop-side injection point (``launch.train.train_loop`` offers
    its params/opt-state pytrees here each step).  Zero-cost passthrough
    when the site is unarmed or gated to a different step."""
    f = _ARMED.get(site)
    if f is None or f.kind not in ("nan", "inf"):
        return value
    if f.step is not None and step != f.step:
        return value
    f.fired += 1
    return _plant_nonfinite(value, f.kind, f.seed)


def _plant_nonfinite(tree, kind: str, seed: int):
    """Deterministically overwrite one element of one float leaf with
    NaN/Inf (seed picks leaf and element)."""
    import jax
    import jax.numpy as jnp
    leaves, tdef = jax.tree_util.tree_flatten(tree)
    float_idx = [i for i, l in enumerate(leaves)
                 if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)
                 and jnp.asarray(l).size > 0]
    if not float_idx:
        return tree
    i = float_idx[seed % len(float_idx)]
    leaf = jnp.asarray(leaves[i])
    bad = jnp.asarray(float("nan") if kind == "nan" else float("inf"),
                      dtype=leaf.dtype)
    flat = jnp.reshape(leaf, (-1,))
    flat = flat.at[seed % flat.shape[0]].set(bad)
    leaves[i] = jnp.reshape(flat, leaf.shape)
    return jax.tree_util.tree_unflatten(tdef, leaves)


# ---------------------------------------------------------------------------
# The chaos matrix — every fault class: inject, detect, attribute, survive
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MatrixRow:
    fault: str
    site: str
    kind: str
    detected: bool
    guard_key: str       # the guard:* / decision-log entry that named it
    survived: bool
    detail: str

    @property
    def ok(self) -> bool:
        return self.detected and self.survived


def _fresh(**autotune_kwargs) -> None:
    stats.reset()
    autotune.reset(**autotune_kwargs)
    disarm()


def _train(*, guard=None, ckpt_dir=None, steps=6, ckpt_every=0, seed=3):
    from repro.configs import SMOKE_ARCHS
    from repro.configs.base import TrainConfig
    from repro.launch.train import train_loop
    cfg = SMOKE_ARCHS["smollm-360m"]
    tcfg = TrainConfig(total_steps=steps, checkpoint_every=ckpt_every,
                       learning_rate=1e-3, seed=seed)
    return train_loop(cfg, tcfg, batch_size=4, seq_len=16, steps=steps,
                      ckpt_dir=ckpt_dir, log_every=0, guard=guard)


def _case_params_nonfinite() -> MatrixRow:
    """NaN planted in params for ONE step → non-finite loss/grads; the
    optimizer skips the update and the master weights regenerate clean
    params — one ``skip`` verdict, no lasting damage."""
    import jax
    from .guards import StepGuard
    _fresh()
    base = _train()["losses"][-1]
    guard = StepGuard()
    arm(Fault("train:params", "nan", step=2, seed=7))
    try:
        out = _train(guard=guard)
    finally:
        disarm()
    jax.effects_barrier()
    g = stats.guard_counts()
    verdicts = [v for _, v in guard.verdicts]
    detected = g.get("guard:nonfinite_skip", 0) >= 1 and "skip" in verdicts
    survived = abs(out["losses"][-1] - base) < 0.5
    return MatrixRow(
        "params-nan-one-step", "train:params", "nan", detected,
        "guard:nonfinite_skip", survived,
        f"verdicts={verdicts} final={out['losses'][-1]:.4f} base={base:.4f}")


def _case_optstate_rollback() -> MatrixRow:
    """NaN planted in optimizer state → PERSISTENT non-finite steps (the
    corruption lives in the master weights, skipping can't heal it); the
    guard escalates past the skip budget to a rollback, restoring the
    newest intact checkpoint, and training converges again."""
    import math
    import tempfile

    import jax
    from .guards import GuardConfig, StepGuard
    _fresh()
    base = _train(steps=10)["losses"][-1]
    guard = StepGuard(GuardConfig(max_consecutive_skips=2))
    arm(Fault("train:opt_state", "nan", step=4, seed=11))
    try:
        with tempfile.TemporaryDirectory() as d:
            out = _train(guard=guard, ckpt_dir=d, steps=10, ckpt_every=2)
    finally:
        disarm()
    jax.effects_barrier()
    g = stats.guard_counts()
    verdicts = [v for _, v in guard.verdicts]
    final = out["losses"][-1]
    detected = g.get("guard:verdict:rollback", 0) >= 1 \
        and g.get("guard:nonfinite_skip", 0) >= 1
    survived = math.isfinite(final) and abs(final - base) < 1.0 \
        and verdicts[-1] == "ok"
    return MatrixRow(
        "optstate-nan-persistent", "train:opt_state", "nan", detected,
        "guard:verdict:rollback", survived,
        f"verdicts={verdicts} final={final:.4f} base={base:.4f}")


def _case_bitmap_flip() -> MatrixRow:
    """Bit flipped in an emitted bitmap → the guard's consistency probe
    catches it, hands back the rescanned (trusted) bitmap, and the degrade
    path books the producing spec as a suspect."""
    import numpy as np

    from repro.core import policy as pol
    from repro.kernels.ops import sparse_gemm
    from .guards import StepGuard, reference_bitmap
    _fresh()
    rng = np.random.default_rng(0)
    a = (rng.standard_normal((16, 12)) * (rng.random((16, 12)) > 0.6)
         ).astype(np.float32)
    b = rng.standard_normal((12, 16)).astype(np.float32)
    P = pol.IN_OUT.with_(kernel_impl="pallas", block=(8, 8, 8))
    dims = (16, 12, 16)
    spec = P.gemm_spec(dims=dims).with_(
        epilogue=("bitmap_emit",), emit_gran=(4, 4))
    guard = StepGuard()
    arm(Fault("gemm:emit_bits", "bitmap_flip", seed=5))
    try:
        out, bits = sparse_gemm(a, b, None, spec=spec)
    finally:
        disarm()
    ok, corrected = guard.probe_emit(out, bits, (4, 4), spec=spec, dims=dims)
    demoted = guard.degrade()
    g = stats.guard_counts()
    ref = reference_bitmap(np.asarray(out), (4, 4))
    detected = (not ok) and g.get("guard:bitmap_mismatch", 0) >= 1 \
        and len(demoted) >= 1
    survived = bool(np.array_equal(np.asarray(corrected), ref)) \
        and np.allclose(np.asarray(out), a @ b, atol=1e-4)
    return MatrixRow(
        "emitted-bitmap-bit-flip", "gemm:emit_bits", "bitmap_flip", detected,
        "guard:bitmap_mismatch", survived,
        f"probe_ok={ok} demoted={[k.stats_key for k in demoted]}")


def _case_queue_overflow_demote() -> MatrixRow:
    """Compact-queue capacity shrunk at dispatch → every dispatch
    overflows (counted, exact fallback); past the threshold the autotuner
    demotes the key off the compact schedule, with a ``demote:overflow``
    decision-log event — the persistently-overflowing spec stops paying
    for queue construction."""
    import numpy as np

    from repro.core import policy as pol
    from repro.kernels.ops import GemmMasks, sparse_gemm
    _fresh(overflow_demote_after=4)
    rng = np.random.default_rng(1)
    a = rng.standard_normal((16, 16)).astype(np.float32)
    b = rng.standard_normal((16, 16)).astype(np.float32)
    mask = np.array([[1, 1], [1, 0]], dtype=np.int32)   # 3 of 4 tiles live
    ref = a @ b
    for i in range(2):
        for j in range(2):
            if not mask[i, j]:
                ref[i * 8:(i + 1) * 8, j * 8:(j + 1) * 8] = 0.0
    P = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    dims = (16, 16, 16)
    exact = True
    arm(Fault("gemm:spec", "queue_overflow", seed=0))
    try:
        for _ in range(6):
            spec = P.gemm_spec(dims=dims)
            out = sparse_gemm(a, b, GemmMasks(out=mask), spec=spec)
            exact = exact and np.allclose(np.asarray(out), ref, atol=1e-4)
        after = P.gemm_spec(dims=dims)
    finally:
        disarm()
    c = stats.counts()
    demote_rows = [r for r in autotune.log_rows()
                   if r["event"] == "demote:overflow"]
    detected = c.get("fallback:queue_overflow", 0) >= 4 \
        and len(demote_rows) >= 1
    survived = exact and after.schedule == "predicated" \
        and c.get("guard:quarantine_clamp", 0) >= 1
    return MatrixRow(
        "compact-queue-overflow", "gemm:spec", "queue_overflow", detected,
        "autotune-log:demote:overflow", survived,
        f"overflows={c.get('fallback:queue_overflow', 0)} "
        f"after_schedule={after.schedule} "
        f"demoted_key={demote_rows[0]['key'] if demote_rows else None}")


def _case_registry_drop() -> MatrixRow:
    """Grad-bitmap registrations dropped → consumers miss their dy masks.
    The miss-counter delta (above the structural baseline — the loss
    cotangent never has a producer) is the detection; numerics must be
    unchanged (a lost mask degrades to lost skipping, never wrong math)."""
    import jax
    import numpy as np

    from repro.core import policy as pol
    from repro.core.sparse_linear import relu_matmul
    from .guards import StepGuard
    _fresh()
    rng = np.random.default_rng(2)
    x = (rng.standard_normal((33, 31)) * (rng.random((33, 31)) > 0.5)
         ).astype(np.float32)
    w1 = rng.standard_normal((31, 24)).astype(np.float32)
    w2 = rng.standard_normal((24, 18)).astype(np.float32)
    P = pol.IN_OUT.with_(kernel_impl="pallas", block=(16, 16, 16))

    def loss(x, w1, w2):
        return (relu_matmul(relu_matmul(x, w1, P), w2, P) ** 2).sum()

    grad = jax.grad(loss, argnums=(0, 1, 2))
    base_grads = grad(x, w1, w2)
    baseline_misses = stats.counts().get("registry:miss", 0)
    guard = StepGuard()
    guard.scan_counters()                      # set the delta baseline
    arm(Fault("registry:register", "registry_drop"))
    try:
        faulted_grads = grad(x, w1, w2)
    finally:
        disarm()
    deltas = guard.scan_counters(
        expected_registry_misses=baseline_misses)
    g = stats.guard_counts()
    detected = deltas["registry:miss"] > baseline_misses \
        and g.get("guard:registry_miss", 0) >= 1
    survived = all(
        np.allclose(np.asarray(gb), np.asarray(gf), atol=1e-5)
        for gb, gf in zip(base_grads, faulted_grads))
    return MatrixRow(
        "grad-bitmap-registry-drop", "registry:register", "registry_drop",
        detected, "guard:registry_miss", survived,
        f"misses: baseline={baseline_misses} faulted={deltas['registry:miss']}")


def _case_collective_drop() -> MatrixRow:
    """One shard's live-block contribution zeroed inside the compressed
    gradient all-reduce → blocks only that shard owned arrive all-zero
    while the psum'd union bitmap still marks them live; the guard's
    consistency probe (``probe_emit`` on the summed gradient against the
    union bits) flags the disagreement, and the summed grad-norm drops
    below the clean reduce's.  Survival: the dense path sits OUTSIDE the
    tamper point — the same reduce with ``cutoff=1.0`` (capacity ≥ every
    block ⇒ dense psum) is exact under the still-armed fault, which is
    precisely the degradation ladder's fallback story."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding import collectives
    from .guards import StepGuard
    _fresh()
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    gran, m, n = (4, 4), 16, 16                # 4×4 grid = 16 blocks
    rng = np.random.default_rng(3)
    # Shard 0 (the one the seed drops) exclusively owns block (0, 0);
    # blocks (1, 1) and (2, 2) are live on every shard; the rest is dead.
    shards = np.zeros((n_dev, m, n), np.float32)
    shards[0, 0:4, 0:4] = rng.standard_normal((4, 4))
    for s in range(n_dev):
        shards[s, 4:8, 4:8] = rng.standard_normal((4, 4))
        shards[s, 8:12, 8:12] = rng.standard_normal((4, 4))
    bms = (np.abs(shards).reshape(n_dev, 4, 4, 4, 4).sum(axis=(2, 4)) > 0
           ).astype(np.int32)

    def _reduce(cutoff):
        def body(xs, bs):
            return collectives.sparse_psum(
                xs[0], bs[0], gran, axis_name="data", cutoff=cutoff,
                return_bits=True)
        return jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P(), P()), check_rep=False))

    guard = StepGuard()
    fault = arm(Fault("collective:allreduce", "drop_contrib", seed=0))
    try:
        out, union = _reduce(0.5)(jnp.asarray(shards), jnp.asarray(bms))
        out_dense, _ = _reduce(1.0)(jnp.asarray(shards), jnp.asarray(bms))
    finally:
        disarm()
    ref = shards.sum(0)
    ok_probe, _ = guard.probe_emit(np.asarray(out), np.asarray(union), gran)
    g = stats.guard_counts()
    c = stats.counts()
    norm_drop = 1.0 - float(np.linalg.norm(np.asarray(out))
                            / np.linalg.norm(ref))
    detected = (not ok_probe) and g.get("guard:bitmap_mismatch", 0) >= 1 \
        and fault.fired >= 1 and norm_drop > 0.0
    survived = bool(np.allclose(np.asarray(out_dense), ref, atol=1e-5)) \
        and c.get("collective:dense", 0) >= 1
    return MatrixRow(
        "collective-drop-contrib", "collective:allreduce", "drop_contrib",
        detected, "guard:bitmap_mismatch", survived,
        f"probe_ok={ok_probe} norm_drop={norm_drop:.3f} "
        f"dense_exact={survived} devices={n_dev}")


def _case_ckpt_crash_mid_save() -> MatrixRow:
    """Checkpoint writer dies between the payload write and the commit
    rename → the partial ``.tmp`` dir is never visible as a checkpoint,
    restore lands on the previous intact step, and the next save clears
    the wreckage."""
    import os
    import tempfile

    import jax.numpy as jnp
    import numpy as np

    from repro import checkpoint as ckpt
    _fresh()
    tree2 = {"w": jnp.arange(6, dtype=jnp.float32)}
    tree4 = {"w": jnp.arange(6, dtype=jnp.float32) * 2}
    crashed = wreckage = False
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 2, tree2)
        arm(Fault("checkpoint:pre_commit", "crash"))
        try:
            ckpt.save(d, 4, tree4)
        except InjectedCrash:
            crashed = True
        finally:
            disarm()
        wreckage = any(n.endswith(".tmp") for n in os.listdir(d))
        visible = ckpt.latest_step(d)
        step, back = ckpt.restore(d, tree2)
        restored_prev = step == 2 and np.array_equal(
            np.asarray(back["w"]), np.asarray(tree2["w"]))
        ckpt.save(d, 4, tree4)                 # healthy retry
        cleaned = not any(n.endswith(".tmp") for n in os.listdir(d))
    detected = crashed and wreckage and visible == 2
    survived = restored_prev and cleaned
    return MatrixRow(
        "ckpt-crash-pre-commit", "checkpoint:pre_commit", "crash", detected,
        "commit-protocol", survived,
        f"crashed={crashed} visible={visible} cleaned={cleaned}")


def _case_ckpt_corrupt_newest() -> MatrixRow:
    """Newest COMMITTED checkpoint corrupted on disk (truncated payload) →
    auto-resume detects the typed corruption, counts the fallback, lands
    on the previous intact step and quarantines the wreck."""
    import os
    import tempfile

    import jax.numpy as jnp
    import numpy as np

    from repro import checkpoint as ckpt
    _fresh()
    tree2 = {"w": jnp.arange(6, dtype=jnp.float32)}
    tree4 = {"w": jnp.arange(6, dtype=jnp.float32) * 2}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 2, tree2)
        ckpt.save(d, 4, tree4)
        npz = os.path.join(d, "step_00000004", "leaves.npz")
        with open(npz, "r+b") as f:
            f.truncate(16)                     # torn write
        step, back = ckpt.restore(d, tree2)
        quarantined = any(n.endswith(".corrupt") for n in os.listdir(d))
        g = stats.guard_counts()
        restored_prev = step == 2 and np.array_equal(
            np.asarray(back["w"]), np.asarray(tree2["w"]))
    detected = g.get("guard:ckpt_fallback", 0) >= 1 and quarantined
    survived = restored_prev
    return MatrixRow(
        "ckpt-corrupt-newest", "checkpoint:pre_commit", "crash", detected,
        "guard:ckpt_fallback", survived,
        f"fallbacks={g.get('guard:ckpt_fallback', 0)} "
        f"quarantined={quarantined}")


CASES: List[Callable[[], MatrixRow]] = [
    _case_params_nonfinite,
    _case_optstate_rollback,
    _case_bitmap_flip,
    _case_queue_overflow_demote,
    _case_registry_drop,
    _case_collective_drop,
    _case_ckpt_crash_mid_save,
    _case_ckpt_corrupt_newest,
]


def run_matrix(names: Optional[List[str]] = None) -> List[MatrixRow]:
    """Run the fault catalogue (optionally filtered by substring) and
    return one row per case.  Each case isolates its own stats/autotune
    state and disarms its faults on the way out."""
    rows = []
    for case in CASES:
        label = case.__name__.replace("_case_", "")
        if names and not any(n in label for n in names):
            continue
        try:
            rows.append(case())
        except Exception as e:                     # noqa: BLE001
            rows.append(MatrixRow(label, "?", "?", False, "", False,
                                  f"case crashed: {e!r}"))
        finally:
            disarm()
    _fresh()
    return rows


def write_csv(rows: List[MatrixRow], path: str) -> None:
    import csv
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["fault", "site", "kind", "detected", "guard_key",
                    "survived", "ok", "detail"])
        for r in rows:
            w.writerow([r.fault, r.site, r.kind, r.detected, r.guard_key,
                        r.survived, r.ok, r.detail])


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Chaos matrix: inject every fault class, assert each "
                    "is detected, attributed and survived.")
    ap.add_argument("--matrix", action="store_true",
                    help="run the full fault catalogue")
    ap.add_argument("--only", nargs="*", default=None,
                    help="substring filter on case names")
    ap.add_argument("--csv", default=None, help="write results as CSV")
    ap.add_argument("--fail-on-undetected", action="store_true",
                    help="exit 1 if any fault goes undetected or unsurvived")
    args = ap.parse_args(argv)
    if not args.matrix:
        ap.print_help()
        return 0
    rows = run_matrix(args.only)
    width = max(len(r.fault) for r in rows) + 2
    for r in rows:
        mark = "PASS" if r.ok else "FAIL"
        print(f"{mark}  {r.fault:<{width}} detected={str(r.detected):<5} "
              f"survived={str(r.survived):<5} via {r.guard_key}")
        print(f"      {r.detail}")
    if args.csv:
        write_csv(rows, args.csv)
        print(f"wrote {args.csv}")
    bad = [r for r in rows if not r.ok]
    print(f"{len(rows) - len(bad)}/{len(rows)} fault classes detected "
          f"and survived")
    if bad and args.fail_on_undetected:
        return 1
    return 0


if __name__ == "__main__":
    # ``python -m repro.runtime.faults`` executes this file as __main__,
    # while the train loop imports ``repro.runtime.faults`` — two module
    # instances, two _ARMED dicts.  Delegate to the canonical instance so
    # armed faults are the ones the instrumented layers consult.
    from repro.runtime import faults as _canonical
    sys.exit(_canonical.main())
