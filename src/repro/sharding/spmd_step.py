"""``shard_map`` training step — the sparse engine under explicit SPMD.

The jit partitioner would happily shard the training step on its own, but
it cannot know the sparsity contracts: which psums may be compressed by
which bitmap, and that per-shard masks must be SLICES of the single
forward bitmap rather than per-shard rescans.  ``shard_map`` makes both
explicit:

  * the batch is sharded on its leading dim over the data-parallel axes;
    each shard's forward pass runs the SAME fused relu_encode on its rows,
    so the shard's ``SparseTensor`` bitmap IS the row-slice of the global
    bitmap (bitmaps tile rows at granularity ``gran[0]`` and shards split
    on row boundaries — `partition.bitmap_pspec` enforces the same
    alignment for explicitly sharded carriers).  The body is traced ONCE
    for all shards, so ``bitmap_op_audit`` still sees exactly one encode
    per activation per step across the whole mesh, and zero rescans;
  * every GEMM inside the body sees shard-LOCAL dims and resolves its own
    ``GemmSpec`` through the one ``SparsityPolicy.gemm_spec``/autotune
    path — per-shard dataflow selection (SparseTrain's point) falls out of
    the existing machinery;
  * the gradient all-reduce goes through
    ``sharding.collectives.psum_grads``: WG bitmaps registered by the
    backward pass compress the wire traffic, everything else takes the
    tagged dense psum.

``check_rep=False`` throughout: the bodies route through Pallas kernels
(custom_vjp + pallas_call), for which shard_map's replication checker has
no rules.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import collectives, partition

# Compressed-collective capacity as a fraction of the block count; above
# this union live fraction the all-reduce falls back to dense psum
# (docs/sharding.md#cutoff).
DEFAULT_CUTOFF = 0.5


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    axes = partition.dp_axis_names(mesh)
    if not axes:
        raise ValueError(
            f"mesh {mesh.axis_names} has no data-parallel axis "
            "('data'/'pod') to shard the batch over")
    return axes


def make_spmd_grad_fn(loss_fn: Callable[[Any, Any], Any], mesh: Mesh, *,
                      cutoff: float = DEFAULT_CUTOFF,
                      block: Optional[Tuple[int, int]] = None):
    """jit(shard_map) of ``loss_fn(params, batch) -> scalar mean loss``.

    Returns ``f(params, batch) -> (loss, grads)`` where ``batch`` is
    globally batched on its leading dim and the outputs are the GLOBAL
    mean loss and mean gradients — numerically the single-device
    ``value_and_grad`` of the same loss over the full batch (to psum
    accumulation-order tolerance; asserted in
    tests/test_sparse_collectives.py)."""
    axes = data_axes(mesh)
    inv = 1.0 / partition.axis_size(mesh, axes)

    def body(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = collectives.psum_grads(grads, axis_name=axes, cutoff=cutoff,
                                       block=block)
        loss = collectives.psum_scalar(loss, axes)
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(), P(tuple(axes))),
        out_specs=(P(), P()), check_rep=False))


def make_spmd_train_step(cfg, opt_cfg, mesh: Mesh, *,
                         cutoff: float = DEFAULT_CUTOFF,
                         block: Optional[Tuple[int, int]] = None):
    """The LM training step of ``launch.steps.make_train_step``, as an
    explicit shard_map: (params, opt_state, batch) -> same triple, with
    params/opt replicated, the batch data-sharded, and the gradient
    all-reduce bitmap-compressed.  Gradient-accumulation microbatching is
    the jit path's feature; here the mesh IS the batch split
    (train_loop asserts microbatches == 1 in spmd mode)."""
    from repro.models.transformer import lm_loss
    from repro.optim.optimizer import adamw_update
    axes = data_axes(mesh)
    inv = 1.0 / partition.axis_size(mesh, axes)

    def body(params, opt_state, batch):
        def loss_fn(p):
            loss = lm_loss(p, batch, cfg)
            if opt_cfg.loss_scale > 0:
                return loss * opt_cfg.loss_scale, loss
            return loss, loss

        (_, loss), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads = collectives.psum_grads(grads, axis_name=axes, cutoff=cutoff,
                                       block=block)
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = collectives.psum_scalar(loss, axes) * inv
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    step = shard_map(body, mesh=mesh,
                     in_specs=(P(), P(), P(tuple(axes))),
                     out_specs=(P(), P(), P()), check_rep=False)
    return jax.jit(step, donate_argnums=(0, 1))
