"""Activation-sharding context.

Model code annotates tensors by *logical* name (``constraint(x, "act_btd")``)
and stays mesh-agnostic; the launcher installs a rules table mapping logical
names → PartitionSpec for the active mesh.  When no rules are installed
(unit tests, single-device smoke runs) the calls are no-ops, so the model
zoo runs identically on 1 device and on the 512-chip production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_state = threading.local()


def current_rules() -> Optional[Dict[str, PartitionSpec]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: Optional[Dict[str, PartitionSpec]]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constraint(x, name: str):
    """Apply a named sharding constraint if rules are installed, else no-op."""
    rules = current_rules()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])
