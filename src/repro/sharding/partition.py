"""Parameter / activation / cache partitioning rules (DP, TP, EP, SP, FSDP).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
``pod``+``data`` together form the data-parallel (and FSDP/ZeRO) domain;
``model`` carries tensor/expert parallelism.

All rules are *divisibility-guarded*: a dimension is only sharded if the
axis size divides it, otherwise the rule degrades (next candidate dim, or
replication) — so the same rule table serves every arch (14-head internvl,
8-expert grok, 262k-vocab gemma) without per-arch spec tables.  The
`fsdp` flag additionally spreads the largest replicated dim of every large
param over the data domain (params+optimizer ⇒ ZeRO-ish), which is what
lets the 314B/398B configs fit 16 GB/chip.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def normalize_path(keystr_path: str) -> str:
    """jax keystr "['layers']['b0']['attn']['wq']" → "layers/b0/attn/wq"."""
    return keystr_path.replace("']['", "/").replace("['", "").replace("']", "") \
        .replace("[", "/").replace("]", "")


def dp_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (path regex, per-dim logical role) — roles: "model" (TP/EP candidate),
# "fsdp" (FSDP candidate), None.  First match wins; dims listed from 0.
_PARAM_RULES = [
    # embed is vocab-sharded.  A d-sharded table would make the lookup
    # gather local, but XLA's partitioner crashes on that pattern
    # ("Slice dim size … greater than dynamic slice dimension", tested on
    # jax 0.8.2) — so we keep V:model and pay the partitioner's u32
    # select-mask tensors on the big-vocab cells (quantified in
    # EXPERIMENTS.md §Perf as a known-cost refuted iteration).
    (r"embed$",                ("model", "fsdp")),       # (V, d) vocab-TP
    (r"lm_head$",              ("fsdp", "model")),       # (d, V)
    (r"frontend_proj$",        (None, "model")),
    (r"(norm|scale|bias|b_i$|b_f$|dt_bias|d_skip|conv_b)", None),
    # attention
    (r"attn.*w[qkv]$",         ("fsdp", "model")),       # (d, H·Dh)
    (r"attn.*wo$",             ("model", "fsdp")),       # (H·Dh, d)
    (r"attn.*wdkv$",           ("fsdp", None)),          # (d, r) small
    (r"attn.*wkr$",            (None, None)),
    (r"attn.*wu[kv]$",         (None, "model")),         # (r, H·dim)
    (r"cross.*w[qkv]$",        ("fsdp", "model")),
    (r"cross.*wo$",            ("model", "fsdp")),
    # dense FFN
    (r"ffn.*w_(gate|up)$",     ("fsdp", "model")),       # (d, ff)
    (r"ffn.*w_down$",          ("model", "fsdp")),       # (ff, d)
    (r"shared.*w_(gate|up)$",  ("fsdp", "model")),
    (r"shared.*w_down$",       ("model", "fsdp")),
    # MoE experts (E, d, f) / (E, f, d): EP on E, fallback TP on f
    (r"moe.*router$",          (None, None)),
    (r"moe.*w_(gate|up)$",     ("model", "fsdp", "model_alt")),
    (r"moe.*w_down$",          ("model", "model_alt", "fsdp")),
    # Mamba
    (r"ssm.*w_in$",            ("fsdp", "model")),       # (d, 2di)
    (r"ssm.*conv_w$",          (None, "model")),
    (r"ssm.*w_x$",             ("model", None)),         # (di, r+2ds)
    (r"ssm.*w_dt$",            (None, "model")),         # (r, di)
    (r"ssm.*a_log$",           ("model", None)),
    (r"ssm.*w_out$",           ("model", "fsdp")),       # (di, d)
    # xLSTM
    (r"mlstm.*w_up$",          ("fsdp", "model")),
    (r"mlstm.*w_[qkv]$",       (None, "model")),
    (r"mlstm.*w_[if]$",        (None, None)),
    (r"mlstm.*w_down$",        ("model", "fsdp")),
    (r"slstm.*w_ifzo$",        ("fsdp", "model")),
    (r"slstm.*r_[ifzo]$",      None),                    # small recurrent mats
    (r"slstm.*w_ff1$",         ("fsdp", "model")),
    (r"slstm.*w_ff2$",         ("model", "fsdp")),
    # CNN / generic heads
    (r"head",                  (None, None)),
    (r"\bw$",                  None),
]

_FSDP_MIN_SIZE = 1 << 22      # only FSDP-shard params ≥ 4M elements


def _divisible(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               *, fsdp: bool = False, scan_outer: bool = False) -> P:
    """PartitionSpec for one param.  ``scan_outer``: leading period axis
    (stacked layers) — never sharded, prepended as None."""
    dims = list(shape[1:]) if scan_outer else list(shape)
    rule = None
    for pat, r in _PARAM_RULES:
        if re.search(pat, path):
            rule = r
            break
    model_n = axis_size(mesh, "model")
    dp = dp_axis_names(mesh)
    dp_n = axis_size(mesh, dp)
    spec: list = [None] * len(dims)
    if rule is not None:
        model_used = False
        for i, role in enumerate(rule[:len(dims)]):
            if role == "model" and not model_used and _divisible(dims[i], model_n):
                spec[i] = "model"
                model_used = True
        if not model_used:          # fallback: model_alt slots
            for i, role in enumerate(rule[:len(dims)]):
                if role == "model_alt" and _divisible(dims[i], model_n):
                    spec[i] = "model"
                    model_used = True
                    break
        if fsdp and int(np.prod(shape)) >= _FSDP_MIN_SIZE:
            for i, role in enumerate(rule[:len(dims)]):
                if role == "fsdp" and spec[i] is None and _divisible(dims[i], dp_n):
                    spec[i] = dp
                    break
    if scan_outer:
        spec = [None] + spec
    return P(*spec)


def _is_scanned(path: str) -> bool:
    return "layers/" in path


def params_pspecs(params_shapes: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """Pytree of PartitionSpec matching a params(-shaped) pytree."""
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    tdef = jax.tree_util.tree_structure(params_shapes)
    out = []
    for kp, leaf in flat:
        path = normalize_path(jax.tree_util.keystr(kp))
        out.append(param_spec(path, tuple(leaf.shape), mesh, fsdp=fsdp,
                              scan_outer=_is_scanned(path)))
    return jax.tree_util.tree_unflatten(tdef, out)


def params_shardings(params_shapes: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspecs(params_shapes, mesh, fsdp=fsdp))


def opt_state_pspecs(opt_shapes: Any, params_shapes: Any, mesh: Mesh,
                     *, fsdp: bool = False) -> Any:
    """mu/nu/master mirror the param specs; scalars replicated."""
    pspecs = params_pspecs(params_shapes, mesh, fsdp=fsdp)
    return {
        "step": P(),
        "mu": pspecs, "nu": pspecs, "master": pspecs,
    }


# ---------------------------------------------------------------------------
# Batch / cache / activation rules
# ---------------------------------------------------------------------------

def batch_pspecs(batch_shapes: Any, mesh: Mesh) -> Any:
    dp = dp_axis_names(mesh)
    dp_n = axis_size(mesh, dp)

    def one(leaf):
        shape = tuple(leaf.shape)
        if len(shape) >= 1 and _divisible(shape[0], dp_n):
            return P(dp, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree.map(one, batch_shapes)


def cache_pspecs(cache_shapes: Any, mesh: Mesh, *, scanned: bool = True) -> Any:
    """Generic chooser for decode caches of any rank.

    Greedy: batch dim → dp domain if divisible, else the longest dim → dp
    (sequence parallelism for batch=1 long-context); then `model` on the
    first remaining divisible dim (kv-heads, latent rank, d_inner, …).
    Leading period axis (scanned stacks) is never sharded.
    """
    dp = dp_axis_names(mesh)
    dp_n = axis_size(mesh, dp)
    model_n = axis_size(mesh, "model")

    def one_path(kp, leaf):
        shape = list(leaf.shape)
        skip = 1 if (scanned and "layers/" in normalize_path(jax.tree_util.keystr(kp))) else 0
        spec: list = [None] * len(shape)
        body = list(range(skip, len(shape)))
        # dp placement: batch dim (first body dim) else longest dim
        dp_dim = None
        if body and _divisible(shape[body[0]], dp_n):
            dp_dim = body[0]
        else:
            cands = [d for d in body[1:] if _divisible(shape[d], dp_n)]
            if cands:
                dp_dim = max(cands, key=lambda d: shape[d])
        if dp_dim is not None:
            spec[dp_dim] = dp
        # model placement: first remaining divisible dim, preferring later
        # (feature-like) dims over sequence dims.
        for d in reversed(body):
            if d != dp_dim and _divisible(shape[d], model_n) and shape[d] >= model_n:
                spec[d] = "model"
                break
        return P(*spec)

    flat = jax.tree_util.tree_flatten_with_path(cache_shapes)[0]
    tdef = jax.tree_util.tree_structure(cache_shapes)
    return jax.tree_util.tree_unflatten(
        tdef, [one_path(kp, leaf) for kp, leaf in flat])


def activation_rules(mesh: Mesh) -> Dict[str, P]:
    dp = dp_axis_names(mesh)
    # NOTE "moe_ecd" (EP pinning of dispatch buffers) was measured to
    # REGRESS memory on every MoE cell (dp-sharded tokens → model-sharded
    # buffer forces a resharding of the scatter; grok 68→107 GiB,
    # deepseek 20→27 GiB) and is deliberately absent — see EXPERIMENTS.md
    # §Perf iteration log.
    return {
        "act_btd": P(dp, None, None),
        # head weight (d, V): V on model for the chunked-xent matmul (the
        # reshard from the d-sharded stored embed is hoisted out of the
        # chunk scan — loop-invariant — so it costs one a2a per microbatch
        # direction, not per chunk).
        "head_dv": P(None, "model"),
    }


def to_shardings(pspec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# SparseTensor bitmap rules
# ---------------------------------------------------------------------------

def _spec_axes(entry) -> Tuple[str, ...]:
    """One PartitionSpec entry → the tuple of mesh axes it names."""
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def bitmap_pspec(data_shape: Tuple[int, int], data_spec: P,
                 gran: Tuple[int, int], mesh: Mesh) -> P:
    """PartitionSpec for a ``SparseTensor``'s fine bitmap, given the spec
    of its (2-D view) data: the bitmap shards along the SAME mesh axes as
    the data, divisibility-guarded like every other rule here — and with
    the stricter alignment the bitmap's meaning demands.  Bitmap cell
    (i, j) covers data tile (gran[0]·i…, gran[1]·j…): a shard boundary may
    therefore never straddle a granularity cell, so a data dim is only
    mirrored onto the bitmap when ``dim % (axis_size · gran) == 0``
    (equivalently: every shard holds a whole number of cells).  Otherwise
    the bitmap dim replicates — conservative, never wrong: a replicated
    bitmap still describes the sharded data, each shard just holds cells
    it doesn't own data for."""
    spec = []
    for dim, entry, g in zip(data_shape, data_spec, gran):
        axes = _spec_axes(entry)
        n = axis_size(mesh, axes)
        spec.append(entry if axes and dim % (n * g) == 0 else None)
    return P(*spec)


def sparse_tensor_pspecs(st, data_spec: P, mesh: Mesh):
    """A ``SparseTensor``-shaped pytree of PartitionSpecs (usable directly
    as a shard_map in/out spec or through ``to_shardings``): the data leaf
    takes ``data_spec``; the bitmap leaf follows ``bitmap_pspec``."""
    from repro.core.sparse_tensor import SparseTensor
    if getattr(st, "bitmap", None) is None:
        return SparseTensor(data_spec, None, None)
    return SparseTensor(
        data_spec,
        bitmap_pspec(tuple(st.data.shape), data_spec, st.gran, mesh),
        st.gran)
