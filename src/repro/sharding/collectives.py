"""Bitmap-aware gradient collectives — sparsity on the wire.

The paper's bitmaps make backward-pass *compute* skippable; this module
makes the same metadata skip *communication* (TensorDash's observation:
sparsity metadata should travel with the tensor onto the interconnect).
A data-parallel gradient all-reduce moves every block of ``dW`` across the
mesh even when the WG masks already proved most blocks are exactly zero.

``sparse_psum`` is the bitmap-aware all-reduce.  Inside a ``shard_map``
body it:

  1. coarsens the (emitted/derived) fine bitmap to the collective block
     granularity (``core.sparse_tensor.coarsen_bitmap`` — the same
     derivation primitive every kernel mask uses; never a rescan);
  2. ``psum``s the TINY block bitmap first (``collective:bitmap_psum``) —
     the union tells every shard which blocks are live *anywhere*;
  3. gathers only the union-live blocks into a compact buffer of STATIC
     capacity ``ceil(cutoff · nblocks)`` (prefix-sum compaction, the same
     scheme as the compact GEMM queue) and ``psum``s that buffer
     (``collective:compressed``), scattering the sums back into zeros —
     exact, because a union-dead block is all-zero on every shard (masks
     may only err toward live, docs/bitmap_lifecycle.md invariant 3);
  4. falls back to a dense ``psum`` (``collective:dense_fallback``) when
     the MEASURED union live count exceeds the capacity — past the cutoff
     the compressed path would lose, so it is never taken.

``dense_psum`` is the tagged dense path (``collective:dense``) used when
no bitmap is available; ``psum_grads`` maps a gradient pytree through
whichever applies, looking up each leaf's bitmap in the grad-bitmap
registry (a peek: misses are structural here and must not feed the
guard's miss-counter deltas).

All cross-shard traffic in the audited workloads flows through these
entry points: every ``psum`` carries a ``repro:collective:*`` lifecycle
scope, and ``analysis/jaxpr_audit.py`` flags any collective primitive
outside one (COLLECTIVE_UNTAGGED).

Fault site (``runtime/faults.py``): ``collective:allreduce`` — an armed
hook may tamper with one shard's compact-buffer contribution (the
transport-corruption fault class).  The dense paths are never tampered:
falling back to ``dense_psum`` is the survival story.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sparse_tensor import coarsen_bitmap, lookup_grad_bitmap
from repro.kernels import stats

AxisNames = Union[str, Sequence[str]]

# Fault-injection tap (repro/runtime/faults.py): an installed hook may
# tamper with the compact buffer one shard contributes to the compressed
# all-reduce.  This layer never imports runtime; faults.py installs here.
_COLLECTIVE_HOOK = None


def set_collective_hook(fn):
    """Install (or, with None, remove) the collective fault hook; returns
    the previous hook.  The hook receives ``(site, contrib, axis_name)``
    and returns the (possibly tampered) contribution."""
    global _COLLECTIVE_HOOK
    prev, _COLLECTIVE_HOOK = _COLLECTIVE_HOOK, fn
    return prev


def _axes(axis_name: AxisNames):
    return axis_name if isinstance(axis_name, str) else tuple(axis_name)


def dense_psum(x: jnp.ndarray, axis_name: AxisNames) -> jnp.ndarray:
    """The tagged dense all-reduce — the path for bitmap-less gradients."""
    stats.record("collective:dense")
    with stats.lifecycle_scope("collective", "dense"):
        return lax.psum(x, _axes(axis_name))


def psum_scalar(x, axis_name: AxisNames):
    """Tagged scalar reduction (losses, metrics) — tiny, always dense."""
    with stats.lifecycle_scope("collective", "scalar"):
        return lax.psum(x, _axes(axis_name))


def _compact_queue(live_flat: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Prefix-sum compaction of live block ids into a fixed-capacity queue
    (the collective analogue of ``kernels.queue_builder``): entry ``q`` is
    the flat block id of the q-th live block; unused slots hold the
    sentinel ``nblk`` (gathers zeros, scatters are dropped)."""
    nblk = live_flat.shape[0]
    pos = jnp.cumsum(live_flat) - 1
    slot = jnp.where((live_flat > 0) & (pos < capacity), pos, capacity)
    queue = jnp.full((capacity + 1,), nblk, jnp.int32)
    queue = queue.at[slot].set(jnp.arange(nblk, dtype=jnp.int32))
    return queue[:capacity]


def _block_view(x: jnp.ndarray, block: Tuple[int, int]):
    """(M, N) → (mt, b0, nt, b1) zero-padded 4-D block view + the grid.

    A PURE reshape (row-major axis split, no transpose): block (i, j) is
    ``view[i, :, j, :]``, so gather/scatter index the view directly and
    the full-size transpose copy a flat ``(mt·nt, b0, b1)`` layout would
    need never materializes — the compressed path's local traffic must
    stay proportional to the CAPACITY, not the tensor."""
    b0, b1 = block
    m, n = x.shape
    mt, nt = -(-m // b0), -(-n // b1)
    if (mt * b0, nt * b1) != (m, n):
        x = jnp.pad(x, ((0, mt * b0 - m), (0, nt * b1 - n)))
    return x.reshape(mt, b0, nt, b1), (mt, nt)


def sparse_psum(x: jnp.ndarray, bitmap: jnp.ndarray,
                gran: Tuple[int, int], *, axis_name: AxisNames,
                block: Optional[Tuple[int, int]] = None,
                cutoff: float = 0.5, return_bits: bool = False):
    """Bitmap-compressed all-reduce of a 2-D gradient across ``axis_name``.

    ``bitmap`` is the shard-local fine bitmap of ``x`` at granularity
    ``gran`` (emitted by the producing GEMM or derived from operand masks
    — NEVER rescanned here).  ``block`` is the wire-block granularity the
    bitmap is coarsened to (default: ``gran`` itself).  ``cutoff`` sets
    the compressed path's static capacity as a fraction of the block
    count; a union live count above it falls back to a dense ``psum`` at
    runtime, so the compressed path never loses correctness or (past the
    cutoff) bandwidth.

    Returns the all-reduced gradient; with ``return_bits=True`` also the
    union live-block mask (``(mt, nt)`` int32) for consistency probes
    (``runtime.guards.StepGuard.probe_emit``).
    """
    assert x.ndim == 2, f"sparse_psum wants a 2-D view, got {x.shape}"
    axes = _axes(axis_name)
    b0, b1 = block or tuple(gran)
    blk = bitmap if (b0, b1) == tuple(gran) \
        else coarsen_bitmap(bitmap, tuple(gran), (b0, b1))
    with stats.lifecycle_scope("collective", "bitmap"):
        stats.record("collective:bitmap_psum")
        union = lax.psum(blk.astype(jnp.int32), axes)
    live = (union > 0).astype(jnp.int32)
    live_flat = live.reshape(-1)
    nblk = int(live_flat.shape[0])
    capacity = max(1, int(math.ceil(cutoff * nblk)))

    if capacity >= nblk:
        # The cutoff admits every block: compression cannot move fewer
        # bytes than the dense reduce, so don't build the machinery.
        out = dense_psum(x, axes)
        return (out, union) if return_bits else out

    count = live_flat.sum()
    overflow = count > capacity
    stats.record_at_runtime("collective:dense_fallback", overflow)
    stats.record_at_runtime("collective:compressed", 1 - overflow)

    def _dense(_):
        with stats.lifecycle_scope("collective", "dense"):
            return lax.psum(x, axes)

    def _compressed(_):
        with stats.lifecycle_scope("collective", "compressed"):
            queue = _compact_queue(live_flat, capacity)
            x4, (mt, nt) = _block_view(x, (b0, b1))
            qi, qj = queue // nt, queue % nt
            # Sentinel id nblk → (mt, 0): the gather CLAMPS out-of-bounds
            # rows (reads a real block's bytes into dead slots — harmless,
            # the scatter below DROPS those slots), so dead queue slots
            # never reach the output.
            contrib = x4[qi, :, qj, :]                   # (capacity, b0, b1)
            if _COLLECTIVE_HOOK is not None:
                contrib = _COLLECTIVE_HOOK(
                    "collective:allreduce", contrib, axes)
            summed = lax.psum(contrib, axes)
            out4 = jnp.zeros((mt, b0, nt, b1), summed.dtype)
            out4 = out4.at[qi, :, qj, :].set(summed)     # sentinels dropped
            return out4.reshape(mt * b0, nt * b1)[
                :x.shape[0], :x.shape[1]].astype(x.dtype)

    out = lax.cond(overflow, _dense, _compressed, None)
    return (out, union) if return_bits else out


def psum_grads(grads: Any, *, axis_name: AxisNames, cutoff: float = 0.5,
               block: Optional[Tuple[int, int]] = None) -> Any:
    """All-reduce a gradient pytree: leaves whose bitmap the backward pass
    registered (``core.sparse_tensor.register_grad_bitmap`` — the WG GEMM
    derives its output bitmap from the operand masks) go through the
    bitmap-compressed path; everything else takes the dense ``psum``.

    The registry consult is a PEEK: most leaves (biases, scalars, conv
    weights the engine didn't annotate) legitimately have no bitmap, and
    those misses must not count against the guard's ``registry:miss``
    delta budget."""
    leaves, tdef = jax.tree_util.tree_flatten(grads)
    out = []
    for leaf in leaves:
        hit = None
        if getattr(leaf, "ndim", 0) == 2:
            hit = lookup_grad_bitmap(leaf, peek=True)
        if hit is not None:
            bitmap, gran = hit
            out.append(sparse_psum(leaf, bitmap, gran, axis_name=axis_name,
                                   block=block, cutoff=cutoff))
        else:
            out.append(dense_psum(leaf, axis_name))
    return jax.tree_util.tree_unflatten(tdef, out)
