"""Distribution layer: mesh-agnostic sharding rules (DP/TP/EP/SP/FSDP)."""
from .context import constraint, sharding_rules, current_rules  # noqa: F401
