"""Distribution layer: mesh-agnostic sharding rules (DP/TP/EP/SP/FSDP)
plus the sparsity-on-the-wire subsystem — bitmap-aware collectives
(``collectives``) and the explicit shard_map training step
(``spmd_step``)."""
from .context import constraint, sharding_rules, current_rules  # noqa: F401
from . import collectives, partition, spmd_step  # noqa: F401
