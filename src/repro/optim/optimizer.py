"""Optimizers: AdamW with f32 master weights (for bf16/fp16 params),
cosine schedule with warmup, global-norm clipping, static loss scaling
(the paper's fp16 training mode, ref. [42]).

Functional: state is a pytree, update is pure, everything jit/pjit-safe.
Master weights live in the optimizer state, so sharding the state over the
data axis gives ZeRO-1 for free when the launcher requests it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    loss_scale: float = 0.0          # 0 → disabled
    emit_guard_stats: bool = False   # count runtime non-finite skips under
                                     # guard:nonfinite_skip via an async
                                     # host callback (the train loop turns
                                     # this on when a StepGuard is
                                     # installed) — otherwise the skip is
                                     # only visible in metrics["skipped"]


def cosine_lr(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.learning_rate * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params: Params, *, moment_dtype=jnp.float32) -> Dict[str, Any]:
    """``moment_dtype=bf16`` halves mu/nu bytes — at 314B+ params on a
    single 256-chip pod, f32 Adam state alone exceeds 16 GB/chip, so
    low-precision moments are load-bearing, not a nicety.  Master weights
    stay f32 (they carry the precision)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    # copy=True: a f32 param would otherwise ALIAS its master weight, and
    # donating both to the train step traps with "donate the same buffer
    # twice".
    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "master": master,
    }


def adamw_update(
    grads: Params,
    state: Dict[str, Any],
    params: Params,
    cfg: OptConfig,
) -> Tuple[Params, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics)."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.loss_scale > 0:
        g32 = jax.tree.map(lambda g: g / cfg.loss_scale, g32)
    gnorm = global_norm(g32)
    # non-finite guard (fp16 overflow): skip the update, keep state.
    finite = jnp.isfinite(gnorm)
    if cfg.emit_guard_stats:
        from repro.kernels import stats
        stats.record_at_runtime("guard:nonfinite_skip",
                                (~finite).astype(jnp.float32))
    clip = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / (gnorm + 1e-9), 1.0)
    g32 = jax.tree.map(lambda g: g * clip, g32)

    step = state["step"] + 1
    lr = cosine_lr(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(m, v, g, w):
        mdt = m.dtype                       # may be bf16 (moment_dtype)
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m1 = b1 * m32 + (1 - b1) * g
        v1 = b2 * v32 + (1 - b2) * g * g
        mhat = m1 / bc1
        vhat = v1 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w
        w1 = w - lr * delta
        # skip on overflow
        m1 = jnp.where(finite, m1, m32).astype(mdt)
        v1 = jnp.where(finite, v1, v32).astype(mdt)
        w1 = jnp.where(finite, w1, w)
        return m1, v1, w1

    flat_mu, tdef = jax.tree.flatten(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_g = jax.tree.leaves(g32)
    flat_w = jax.tree.leaves(state["master"])
    out = [upd(m, v, g, w) for m, v, g, w in
           zip(flat_mu, flat_nu, flat_g, flat_w)]
    new_mu = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_master = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), new_master, params)
    new_state = {"step": jnp.where(finite, step, state["step"]),
                 "mu": new_mu, "nu": new_nu, "master": new_master}
    metrics = {"grad_norm": gnorm, "lr": lr,
               "skipped": (~finite).astype(jnp.float32)}
    return new_params, new_state, metrics
