from .optimizer import (OptConfig, adamw_init, adamw_update,  # noqa: F401
                        cosine_lr, global_norm)
from . import compression  # noqa: F401
