"""int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ node scale the data-parallel all-reduce of f32/bf16 gradients is
a dominant collective; int8 quantization with per-tensor scale cuts its
bytes 4× (vs f32).  Error feedback (residual carried to the next step)
keeps convergence: quantization error is re-injected, so the compressed
SGD trajectory tracks the exact one (Karimireddy et al., 2019).

``compressed_psum`` runs inside ``shard_map`` over the data axes: quantize
(+error feedback) → all-reduce int32-accumulated int8 payload → dequantize
with an all-reduced scale.  The error state is step-carried like optimizer
state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q_int8, scale, new_err).  g, err: same-shape f32."""
    target = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(target)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_err = target - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads: Any, err: Any, axis_names) -> Tuple[Any, Any]:
    """Mean-all-reduce grads over ``axis_names`` with int8 payload.

    Must be called inside shard_map with those axes.  Returns
    (mean_grads_f32, new_err)."""
    n = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list)) else [axis_names]):
        # jax.lax.axis_size only exists on newer jax; psum(1) is the
        # version-stable way to read a mapped axis size inside shard_map.
        n = n * jax.lax.psum(1, a)

    def one(g, e):
        q, scale, e1 = quantize(g, e)
        # accumulate in int32 to avoid int8 overflow across replicas;
        # scales differ per replica → reduce payload and scale separately
        # (sum of per-replica dequantized tensors == psum of q*scale).
        summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis_names)
        return summed / n, e1

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
