"""The paper's fused CONV/GEMM–ReLU unit for dense (GEMM) layers.

``relu_matmul(x_pre, w)`` computes ``relu(x_pre) @ w`` with a custom VJP
that realizes all three of the paper's skipping opportunities:

  forward   : INPUT sparsity of relu(x_pre)        (skip zero activations)
  backward  : dx_pre = (dy @ Wᵀ) ⊙ σ'(x_pre)
              — OUTPUT sparsity: tiles σ' kills are never computed (works
                even when a normalization layer sits between producer and
                ReLU, the paper's headline case);
              — INPUT sparsity of dy (zero gradient tiles skipped);
  wt-grad   : dW = relu(x_pre)ᵀ @ dy — INPUT sparsity on both operands.

Sparsity metadata lifecycle (the FP/BP correlation, made structural): the
forward pass computes the activation's fine bitmap EXACTLY ONCE — via the
fused ``kernels.relu_encode`` pass that also applies the ReLU — and stashes
it in the VJP residual as a ``SparseTensor``.  The backward pass then
*derives* its out_mask (dX GEMM) and transposed operand mask (dW GEMM) from
that bitmap by re-tiling, and scans the incoming gradient at most once,
sharing the result between both backward GEMMs.  No dense tensor is ever
scanned twice (audited by benchmarks/kernel_audit.bitmap_op_audit; the
mask-derivation contract is documented in docs/bitmap_lifecycle.md).

The op is *exact*: its VJP equals dense autodiff of relu→matmul bit-for-bit
on the masked-out entries and to accumulation-order tolerance elsewhere
(property-tested in tests/test_sparse_grad.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import stats
from repro.kernels.ops import GemmMasks, GemmSpec
# The freshly-computed dense-scan ORACLE the threaded bitmaps are
# property-tested against — now lives in kernels.shapes (re-exported under
# the established name).
from repro.kernels.shapes import block_bitmap as _bitmap_padded  # noqa: F401
from .policy import SparsityPolicy
from .sparse_tensor import (
    SparseTensor,
    linear_act_granularity,
    linear_grad_granularity,
    lookup_grad_bitmap,
    register_grad_bitmap,
    scan_bitmap,
)


def _mm(a, b, out_mask, a_mask, b_mask, policy: SparsityPolicy, out_dtype,
        epilogue: Optional[jnp.ndarray] = None,
        spec: Optional[GemmSpec] = None,
        emit_gran: Optional[Tuple[int, int]] = None):
    """Route one masked matmul through the ``kernels.ops.sparse_gemm``
    dispatcher, resolving the policy to a ``GemmSpec`` (unless the caller
    already resolved one — the conv engine passes specs carrying degenerate
    per-group tiles).

    ``epilogue`` is an (M, N) Hadamard multiplier fused into the kernel's
    accumulator writeback (``policy.fuse_epilogue``) or applied as a
    separate elementwise pass (ablation; the "dense" schedule folds it in
    either way — numerics are identical).

    ``emit_gran`` requests the ``bitmap_emit`` writeback stage: the return
    value becomes ``(out, bits_or_None)`` where ``bits`` is the output's
    packed any-nonzero bitmap at that granularity, emitted in the same
    writeback as the (post-σ′) values.  ``None`` bits mean the emission was
    dropped — the ablation path (bits must describe the post-σ′ values the
    separate VPU pass hasn't applied yet) or a resolved tile the
    granularity doesn't divide (autotuning may shrink edges) — and the
    caller proceeds without a mask, never with a rescan.

    3-D operands (leading group axis: (G, M, K) @ (G, K, N)) dispatch as a
    grouped spec — the GEMM form of grouped/depthwise convs, with
    per-group masks and the same epilogue/compact-queue semantics."""
    groups = a.shape[0] if a.ndim == 3 else 1
    if spec is None:
        spec = policy.gemm_spec(groups=groups)
    masks = GemmMasks(out_mask, a_mask, b_mask)
    # σ′ ablation: unfused epilogue runs as a separate VPU pass after an
    # f32 GEMM (only meaningful for real kernel launches; the dense
    # schedule has no writeback to fuse into).
    if epilogue is not None and spec.schedule != "dense" \
            and not policy.fuse_epilogue:
        out = kops.sparse_gemm(
            a, b, masks,
            spec.with_(epilogue="none", emit_gran=None,
                       out_dtype=jnp.float32))
        out = (out * epilogue.astype(jnp.float32)).astype(out_dtype)
        return (out, None) if emit_gran is not None else out
    if emit_gran is not None and (spec.block[0] % emit_gran[0]
                                  or spec.block[2] % emit_gran[1]):
        emit_gran = None
        dropped_emit = True
    else:
        dropped_emit = False
    stages = []
    if epilogue is not None:
        stages.append("sigma_prime")
    if emit_gran is not None:
        stages.append("bitmap_emit")
    spec = spec.with_(epilogue=tuple(stages), emit_gran=emit_gran,
                      out_dtype=out_dtype)
    res = kops.sparse_gemm(a, b, masks, spec, epilogue_mult=epilogue)
    if dropped_emit:
        return res, None
    return res


def _needs_act_bitmap(policy: SparsityPolicy) -> bool:
    """Does any consumer of an activation bitmap exist under this policy?
    Operand masks feed only the pallas kernels; out_mask also drives the
    xla_ref masking path."""
    if policy.use_output_sparsity:
        return True
    return policy.kernel_impl == "pallas" and (
        policy.use_input_sparsity_fp or policy.use_input_sparsity_bp)


def _needs_grad_bitmap(policy: SparsityPolicy) -> bool:
    return policy.kernel_impl == "pallas" and policy.use_input_sparsity_bp


def _grad_sparse_tensor_linear(dy, dy32, policy: SparsityPolicy
                               ) -> SparseTensor:
    """The incoming gradient's ``SparseTensor`` for a GEMM layer's backward
    pass — the dy bitmap comes from the PRODUCING dX GEMM's writeback
    epilogue (registered against the exact cotangent object), never from a
    rescan.  A registry miss (raw cotangent from the loss, a producer that
    dropped emission, a rewrapped value) degrades to no mask: skipping is
    lost for this dy, numerics are untouched."""
    if not _needs_grad_bitmap(policy):
        return SparseTensor(dy32, None, None)
    hit = lookup_grad_bitmap(dy)
    if hit is None:
        return SparseTensor(dy32, None, None)
    bitmap, (gr, gc) = hit
    bm, bk, bn = policy.block
    # The emitted granularity must serve BOTH backward masks this layer
    # derives: a-operand (bm, bk) for dX and b-operand (bk, bn) for dW.
    if bm % gr or bk % gr or bk % gc or bn % gc:
        return SparseTensor(dy32, None, None)
    return SparseTensor(dy32, bitmap, (gr, gc))


def _wg_bitmap(xt_mask, dyb_mask, kt: int, mt: int, nt: int):
    """Derive the weight-gradient's block bitmap from the WG GEMM's two
    operand masks: dW tile (i, j) can be nonzero only if SOME reduction
    block m has both x̃ᵀ(i, m) and dy(m, j) live.  Pure mask algebra
    (broadcast-AND, any-reduce over the reduction blocks) — no dense data
    is touched, and deliberately NOT a dot_general: mask derivation must
    never look like an untagged GEMM to the static auditor.  Exact on the
    dead side (every partial product has an all-zero operand tile ⇒ the
    dW block is exactly zero), conservative on the live side — precisely
    the contract the bitmap-compressed gradient all-reduce
    (sharding/collectives) relies on.  A missing operand mask degrades to
    all-live on that side; both missing means no bitmap (dense collective).
    """
    if xt_mask is None and dyb_mask is None:
        return None
    with stats.lifecycle_scope("derive", "wg"):
        a = xt_mask.astype(jnp.int32) if xt_mask is not None \
            else jnp.ones((kt, mt), jnp.int32)
        b = dyb_mask.astype(jnp.int32) if dyb_mask is not None \
            else jnp.ones((mt, nt), jnp.int32)
        return ((a[:, :, None] * b[None, :, :]).sum(axis=1) > 0) \
            .astype(jnp.int32)


# ---------------------------------------------------------------------------
# relu_matmul — the composable unit
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def act_matmul(x_pre: jnp.ndarray, w: jnp.ndarray, policy: SparsityPolicy,
               act: str = "relu"):
    """y = act(x_pre) @ w, sparse-aware in both passes. x_pre: (T, K), w: (K, N).

    act ∈ {"relu", "relu2"}.  Both have σ'(z) = 0 ⇔ z ≤ 0, so the zero
    FOOTPRINT of the backward Hadamard is the forward activation footprint
    in either case (relu² is the beyond-paper transformer-FFN variant).
    """
    y, _ = _act_matmul_fwd(x_pre, w, policy, act)
    return y


def _act(x_pre, act: str):
    r = jnp.maximum(x_pre, jnp.zeros((), x_pre.dtype))
    return jnp.square(r) if act == "relu2" else r


def _act_grad_multiplier(x_pre, act: str):
    if act == "relu2":
        return 2.0 * jnp.maximum(x_pre.astype(jnp.float32), 0.0)
    return (x_pre > 0).astype(jnp.float32)


def _encode_act(x_pre: jnp.ndarray, policy: SparsityPolicy,
                gran: Tuple[int, int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(relu(x_pre), fine bitmap) — fused Pallas pass on the pallas impl,
    one counted jnp scan on xla_ref.  Either way: ONE bitmap computation."""
    if policy.kernel_impl == "pallas":
        return kops.relu_encode(x_pre, block=gran, interpret=policy.interpret)
    r = jnp.maximum(x_pre, jnp.zeros((), x_pre.dtype))
    return r, scan_bitmap(r, gran, kind="act")


def _act_matmul_fwd(x_pre, w, policy: SparsityPolicy, act: str):
    bm, bk, bn = policy.block
    if _needs_act_bitmap(policy):
        gran = linear_act_granularity(policy.block)
        r, bitmap = _encode_act(x_pre, policy, gran)
        x = jnp.square(r) if act == "relu2" else r
        st = SparseTensor(x_pre, bitmap, gran)
    else:
        x = _act(x_pre, act)
        st = SparseTensor(x_pre, None, None)
    a_mask = None
    if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas":
        a_mask = st.mask_for((bm, bk))
    y = _mm(x, w, None, a_mask, None, policy, x_pre.dtype)
    return y, (st, w)


def _act_matmul_bwd(policy: SparsityPolicy, act: str, res, dy):
    st, w = res
    x_pre = st.data
    mult = _act_grad_multiplier(x_pre, act)       # zero exactly where x_pre<=0
    x = _act(x_pre, act)
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)

    # The incoming gradient is NEVER rescanned: its bitmap was emitted by
    # the producing dX GEMM's writeback epilogue (looked up by cotangent
    # identity); both backward GEMMs derive their operand masks from it.
    st_dy = _grad_sparse_tensor_linear(dy, dy32, policy)

    # --- dx_pre = (dy @ Wᵀ) ⊙ σ'(x_pre): OUTPUT (+INPUT) sparsity ---
    # out_mask = the forward ReLU bitmap, re-tiled: footprint(σ'(x_pre)) ==
    # footprint(relu(x_pre)) — the paper's §3.2 identity, zero recompute.
    # This GEMM produces the NEXT layer's dy, so it emits that layer's
    # bitmap in the same writeback that applies σ′.
    out_mask = st.mask_for((bm, bn)) if policy.use_output_sparsity else None
    dy_mask = st_dy.mask_for((bm, bk))
    emit = linear_grad_granularity(policy.block) \
        if _needs_grad_bitmap(policy) else None
    res = _mm(dy32, w.astype(jnp.float32).T, out_mask, dy_mask, None,
              policy, x_pre.dtype, epilogue=mult, emit_gran=emit)
    if emit is not None:
        dx_pre, dx_bits = res
        register_grad_bitmap(dx_pre, dx_bits, emit)
    else:
        dx_pre = res

    # --- dW = xᵀ @ dy: INPUT sparsity on both operands (WG stage) ---
    # Xᵀ's mask is the SAME forward bitmap, block-transposed.
    xt = x.astype(jnp.float32).T
    xt_mask = st.t_mask_for((bm, bk)) \
        if _needs_grad_bitmap(policy) else None
    dyb_mask = st_dy.mask_for((bk, bn))
    dw = _mm(xt, dy32, None, xt_mask, dyb_mask, policy, jnp.float32)
    dw = dw.astype(w.dtype)
    # dW crosses the mesh in data-parallel training: register its derived
    # block bitmap (keyed by the EXACT returned object, like the dy
    # hand-off) so sharding/collectives.psum_grads can compress the
    # all-reduce instead of rescanning the gradient.
    register_grad_bitmap(
        dw,
        _wg_bitmap(xt_mask, dyb_mask, -(-w.shape[0] // bm),
                   -(-x_pre.shape[0] // bk), -(-w.shape[1] // bn)),
        (bm, bn))
    return dx_pre, dw


act_matmul.defvjp(_act_matmul_fwd, _act_matmul_bwd)


def relu_matmul(x_pre: jnp.ndarray, w: jnp.ndarray, policy: SparsityPolicy):
    """y = relu(x_pre) @ w — the paper's unit (alias of act_matmul)."""
    return act_matmul(x_pre, w, policy, "relu")


# ---------------------------------------------------------------------------
# plain matmul with FP input sparsity (first layer of a chain, where the
# input is raw data / dense): only input-sparsity opportunities apply, but
# the operand bitmap is still computed once and threaded to the WG stage.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul(x: jnp.ndarray, w: jnp.ndarray, policy: SparsityPolicy):
    y, _ = _matmul_fwd(x, w, policy)
    return y


def _matmul_fwd(x, w, policy: SparsityPolicy):
    bm, bk, bn = policy.block
    st = SparseTensor(x, None, None)
    # Raw (signed) inputs have no ReLU to fuse an encode into, so their
    # bitmap costs a standalone scan — opt-in via scan_signed_inputs (the
    # first layer's input is near-dense, so the scan rarely pays off).
    if policy.scan_signed_inputs and policy.kernel_impl == "pallas" and (
            policy.use_input_sparsity_fp or policy.use_input_sparsity_bp):
        gran = linear_act_granularity(policy.block)
        st = SparseTensor(
            x,
            scan_bitmap(x, gran, kind="act", impl=policy.kernel_impl,
                        interpret=policy.interpret),
            gran)
    a_mask = st.mask_for((bm, bk)) \
        if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas" \
        else None
    y = _mm(x, w, None, a_mask, None, policy, x.dtype)
    return y, (st, w)


def _matmul_bwd(policy: SparsityPolicy, res, dy):
    st, w = res
    x = st.data
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)
    # dy's bitmap comes from the producing GEMM's emit epilogue (the layer
    # above registered it); this layer's dX GEMM emits in turn.
    st_dy = _grad_sparse_tensor_linear(dy, dy32, policy)
    emit = linear_grad_granularity(policy.block) \
        if _needs_grad_bitmap(policy) else None
    res_dx = _mm(dy32, w.astype(jnp.float32).T, None,
                 st_dy.mask_for((bm, bk)), None, policy, x.dtype,
                 emit_gran=emit)
    if emit is not None:
        dx, dx_bits = res_dx
        register_grad_bitmap(dx, dx_bits, emit)
    else:
        dx = res_dx
    xt = x.astype(jnp.float32).T
    xt_mask = st.t_mask_for((bm, bk)) if _needs_grad_bitmap(policy) else None
    dyb_mask = st_dy.mask_for((bk, bn))
    dw = _mm(xt, dy32, None, xt_mask, dyb_mask, policy, w.dtype)
    # Same WG hand-off as act_matmul: the collective consumes it.
    register_grad_bitmap(
        dw,
        _wg_bitmap(xt_mask, dyb_mask, -(-w.shape[0] // bm),
                   -(-x.shape[0] // bk), -(-w.shape[1] // bn)),
        (bm, bn))
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
