"""The paper's fused CONV/GEMM–ReLU unit for dense (GEMM) layers.

``relu_matmul(x_pre, w)`` computes ``relu(x_pre) @ w`` with a custom VJP
that realizes all three of the paper's skipping opportunities:

  forward   : INPUT sparsity of relu(x_pre)        (skip zero activations)
  backward  : dx_pre = (dy @ Wᵀ) ⊙ σ'(x_pre)
              — OUTPUT sparsity: tiles σ' kills are never computed (works
                even when a normalization layer sits between producer and
                ReLU, the paper's headline case);
              — INPUT sparsity of dy (zero gradient tiles skipped);
  wt-grad   : dW = relu(x_pre)ᵀ @ dy — INPUT sparsity on both operands.

The op is *exact*: its VJP equals dense autodiff of relu→matmul bit-for-bit
on the masked-out entries and to accumulation-order tolerance elsewhere
(property-tested in tests/test_sparse_grad.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from .policy import SparsityPolicy


def _bitmap_padded(x2d: jnp.ndarray, b0: int, b1: int) -> jnp.ndarray:
    m, n = x2d.shape
    mp = (m + b0 - 1) // b0 * b0
    np_ = (n + b1 - 1) // b1 * b1
    if mp != m or np_ != n:
        x2d = jnp.pad(x2d, ((0, mp - m), (0, np_ - n)))
    return kref.block_any_nonzero(x2d, b0, b1)


def _mm(a, b, out_mask, a_mask, b_mask, policy: SparsityPolicy, out_dtype):
    """Dispatch a masked matmul through the policy's kernel impl."""
    if policy.kernel_impl == "pallas":
        return kops.masked_matmul(
            a, b, out_mask=out_mask, a_mask=a_mask, b_mask=b_mask,
            block=policy.block, out_dtype=out_dtype,
            compact=policy.work_redistribution, interpret=policy.interpret,
        )
    # xla_ref: numerically-equivalent dense compute + masking.  The skipped
    # work is accounted by core.costmodel, not saved on this backend.
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if out_mask is not None:
        bm, _, bn = policy.block
        m, n = out.shape
        em = kref.expand_block_mask(out_mask.astype(jnp.float32), bm, bn)
        out = out * em[:m, :n]
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# relu_matmul — the composable unit
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def act_matmul(x_pre: jnp.ndarray, w: jnp.ndarray, policy: SparsityPolicy,
               act: str = "relu"):
    """y = act(x_pre) @ w, sparse-aware in both passes. x_pre: (T, K), w: (K, N).

    act ∈ {"relu", "relu2"}.  Both have σ'(z) = 0 ⇔ z ≤ 0, so the zero
    FOOTPRINT of the backward Hadamard is the forward activation footprint
    in either case (relu² is the beyond-paper transformer-FFN variant).
    """
    y, _ = _act_matmul_fwd(x_pre, w, policy, act)
    return y


def _act(x_pre, act: str):
    r = jnp.maximum(x_pre, jnp.zeros((), x_pre.dtype))
    return jnp.square(r) if act == "relu2" else r


def _act_grad_multiplier(x_pre, act: str):
    if act == "relu2":
        return 2.0 * jnp.maximum(x_pre.astype(jnp.float32), 0.0)
    return (x_pre > 0).astype(jnp.float32)


def _act_matmul_fwd(x_pre, w, policy: SparsityPolicy, act: str):
    x = _act(x_pre, act)
    bm, bk, bn = policy.block
    a_mask = None
    if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas":
        a_mask = _bitmap_padded(x.astype(jnp.float32), bm, bk)
    y = _mm(x, w, None, a_mask, None, policy, x_pre.dtype)
    return y, (x_pre, w)


def _act_matmul_bwd(policy: SparsityPolicy, act: str, res, dy):
    x_pre, w = res
    mult = _act_grad_multiplier(x_pre, act)       # zero exactly where x_pre<=0
    x = _act(x_pre, act)
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)

    # --- dx_pre = (dy @ Wᵀ) ⊙ σ'(x_pre): OUTPUT (+INPUT) sparsity ---
    out_mask = _bitmap_padded(mult, bm, bn) \
        if policy.use_output_sparsity else None
    dy_mask = _bitmap_padded(dy32, bm, bk) \
        if policy.use_input_sparsity_bp else None
    dx = _mm(dy32, w.astype(jnp.float32).T, out_mask, dy_mask, None,
             policy, jnp.float32)
    dx_pre = (dx * mult).astype(x_pre.dtype)

    # --- dW = xᵀ @ dy: INPUT sparsity on both operands (WG stage) ---
    xt = x.astype(jnp.float32).T
    xt_mask = _bitmap_padded(xt, bm, bk) if policy.use_input_sparsity_bp else None
    dyb_mask = _bitmap_padded(dy32, bk, bn) if policy.use_input_sparsity_bp else None
    dw = _mm(xt, dy32, None, xt_mask, dyb_mask, policy, jnp.float32)
    return dx_pre, dw.astype(w.dtype)


act_matmul.defvjp(_act_matmul_fwd, _act_matmul_bwd)


def relu_matmul(x_pre: jnp.ndarray, w: jnp.ndarray, policy: SparsityPolicy):
    """y = relu(x_pre) @ w — the paper's unit (alias of act_matmul)."""
    return act_matmul(x_pre, w, policy, "relu")


# ---------------------------------------------------------------------------
# plain matmul with FP input sparsity (first layer of a chain, where the
# input is raw data / dense): only the paper's FP-IN opportunity applies.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul(x: jnp.ndarray, w: jnp.ndarray, policy: SparsityPolicy):
    y, _ = _matmul_fwd(x, w, policy)
    return y


def _matmul_fwd(x, w, policy: SparsityPolicy):
    bm, bk, bn = policy.block
    a_mask = None
    if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas":
        a_mask = _bitmap_padded(x.astype(jnp.float32), bm, bk)
    y = _mm(x, w, None, a_mask, None, policy, x.dtype)
    return y, (x, w)


def _matmul_bwd(policy: SparsityPolicy, res, dy):
    x, w = res
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)
    dy_mask = _bitmap_padded(dy32, bm, bk) if policy.use_input_sparsity_bp else None
    dx = _mm(dy32, w.astype(jnp.float32).T, None, dy_mask, None, policy, x.dtype)
    xt = x.astype(jnp.float32).T
    xt_mask = _bitmap_padded(xt, bm, bk) if policy.use_input_sparsity_bp else None
    dyb_mask = _bitmap_padded(dy32, bk, bn) if policy.use_input_sparsity_bp else None
    dw = _mm(xt, dy32, None, xt_mask, dyb_mask, policy, w.dtype)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
