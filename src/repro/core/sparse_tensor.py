"""SparseTensor — the bitmap-carrier threading FP sparsity into BP.

The paper's core observation (§3.2) is that forward and backward sparsity
are *correlated*: the ReLU bitmap captured while computing the forward pass
IS the output-sparsity mask of the backward pass, and (transposed/re-tiled)
also the input-sparsity mask of the weight-gradient GEMM.  This module makes
that correlation structural:

  * ``SparseTensor`` pairs a dense payload with a FINE-granularity block
    bitmap computed exactly once (by the fused ``kernels.relu_encode`` on
    the hot path, or one counted scan for signed data).  It is a pytree, so
    it rides through ``jax.custom_vjp`` residuals unchanged.
  * Every mask a backward GEMM needs is then *derived* — ``coarsen_bitmap``
    (OR-reduce fine cells into coarser tiles) and ``transpose`` (swap block
    axes) — pure bitmap arithmetic on arrays hundreds-to-thousands of times
    smaller than the activations they describe.  Derivations are exact, not
    conservative: an OR of any-nonzero sub-blocks equals any-nonzero of the
    union, so every derived mask is bit-identical to a fresh dense scan
    (property-tested in tests/test_bitmap_threading.py).

Granularity contract: a bitmap at granularity (gr, gc) can be coarsened to
any block (B0, B1) with gr | B0 and gc | B1, and transposed-then-coarsened
to any (B0, B1) with gc | B0 and gr | B1.  The ``*_granularity`` helpers
below pick the finest granularity that serves every consumer of a tensor,
which degenerates to the block size itself for uniform blocks (zero
overhead in the common case).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels import stats


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def coarsen_bitmap(bitmap: jnp.ndarray, gran: Tuple[int, int],
                   block: Tuple[int, int]) -> jnp.ndarray:
    """(M/gr, N/gc) fine bitmap -> (ceil(M/B0), ceil(N/B1)) coarse bitmap.

    Exact: the coarse cell is the OR of its member fine cells; ragged edges
    are zero-padded (padding describes zero data, so OR-identity).

    A 3-D bitmap is treated as a batch of independent 2-D bitmaps over its
    leading axis — the per-group masks of grouped/depthwise convs, where
    the batch axis IS the group axis and cells never straddle groups.
    """
    gr, gc = gran
    b0, b1 = block
    assert b0 % gr == 0 and b1 % gc == 0, (gran, block)
    f0, f1 = b0 // gr, b1 // gc
    with stats.lifecycle_scope("derive", "coarsen"):
        if bitmap.ndim == 3:
            g, r, c = bitmap.shape
            rp, cp = _ceil_div(r, f0) * f0, _ceil_div(c, f1) * f1
            if rp != r or cp != c:
                bitmap = jnp.pad(bitmap, ((0, 0), (0, rp - r), (0, cp - c)))
            return bitmap.reshape(g, rp // f0, f0, cp // f1, f1) \
                .max(axis=(2, 4)).astype(jnp.int32)
        r, c = bitmap.shape
        rp, cp = _ceil_div(r, f0) * f0, _ceil_div(c, f1) * f1
        if rp != r or cp != c:
            bitmap = jnp.pad(bitmap, ((0, rp - r), (0, cp - c)))
        return bitmap.reshape(rp // f0, f0, cp // f1, f1).max(axis=(1, 3)) \
            .astype(jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseTensor:
    """Dense payload + once-computed fine nonzero bitmap of a 2-D view.

    ``data`` may be the tensor itself (GEMM path) or a 4-D NHWC activation
    whose (N·H·W, C) flattening the bitmap describes (conv path).  ``gran``
    is static metadata; ``bitmap`` is None when the policy needs no
    sparsity metadata (DC), making the carrier free in that case.
    """
    data: jnp.ndarray
    bitmap: Optional[jnp.ndarray]
    gran: Optional[Tuple[int, int]]

    # -- pytree protocol (gran is static aux data) --
    def tree_flatten(self):
        return (self.data, self.bitmap), self.gran

    @classmethod
    def tree_unflatten(cls, gran, children):
        data, bitmap = children
        return cls(data, bitmap, gran)

    # -- mask derivation --
    def mask_for(self, block: Tuple[int, int]) -> Optional[jnp.ndarray]:
        """Block bitmap of the 2-D view at tile shape ``block``."""
        if self.bitmap is None:
            return None
        return coarsen_bitmap(self.bitmap, self.gran, block)

    def t_mask_for(self, block: Tuple[int, int]) -> Optional[jnp.ndarray]:
        """Block bitmap of the TRANSPOSED 2-D view at ``block`` — the WG
        stage's operand mask, derived without touching the data."""
        if self.bitmap is None:
            return None
        gr, gc = self.gran
        return coarsen_bitmap(self.bitmap.T, (gc, gr), block)


# ---------------------------------------------------------------------------
# Granularity selection
# ---------------------------------------------------------------------------

def linear_act_granularity(block: Tuple[int, int, int]) -> Tuple[int, int]:
    """Finest granularity serving an activation X (T, K) of a GEMM layer:
    a_mask (bm, bk) in FP, out_mask (bm, bn) in BP, Xᵀ mask (bm, bk) in WG
    (transposed: needs gc | bm, gr | bk)."""
    bm, bk, bn = block
    gr = math.gcd(bm, bk)
    return gr, math.gcd(gr, bn)


def linear_grad_granularity(block: Tuple[int, int, int]) -> Tuple[int, int]:
    """Finest granularity serving an incoming gradient dY (T, N): a-operand
    mask (bm, bk) for the dX GEMM, b-operand mask (bk, bn) for the dW GEMM."""
    bm, bk, bn = block
    return math.gcd(bm, bk), math.gcd(bk, bn)


def conv_channel_granularity(channels: int,
                             block: Tuple[int, int, int],
                             groups: int = 1) -> int:
    """Channel granularity for a conv tensor's (pixels, channels) view.

    Row granularity is fixed at 1 (per pixel) so the bitmap stays spatially
    addressable — patch (im2col) masks are then *derived* by gathering the
    bitmap itself.  The channel granularity must divide the channel count
    (tap segments in the im2col K-axis must tile evenly) and every block
    edge a derived mask can take (bm for transposed WG masks, bk/bn for
    operand masks).

    Group-boundary contract: for a grouped conv the granularity must also
    divide ``channels // groups``, so no coarsened cell ever straddles two
    groups — a straddling cell would let one group's live data mark another
    group's tile live (conservative, but it breaks the per-group mask
    slicing, which assumes cells nest inside groups).  Depthwise
    (``groups == channels``) degenerates to per-channel granularity 1.
    """
    bm, bk, bn = block
    assert channels % groups == 0, (channels, groups)
    per_group = channels // groups
    return math.gcd(math.gcd(per_group, bm), math.gcd(bk, bn))


# ---------------------------------------------------------------------------
# Backward-pass bitmap hand-off — producer GEMM → consumer layer
# ---------------------------------------------------------------------------

# The dX GEMM of layer L+1 emits the bitmap of its output (the dy of layer
# L) in its writeback epilogue; layer L's backward fn then needs to find
# that bitmap when JAX hands it the cotangent.  Cotangents flow through
# JAX's autodiff machinery, not through user pytrees, so the hand-off is a
# small trace-local registry keyed by OBJECT IDENTITY of the cotangent
# array: the producer registers the exact array object it returns, and the
# consumer looks up the exact object it receives.  Within one trace the
# object is passed through unchanged, so identity holds; across traces (or
# if JAX ever rewraps the value) the lookup just misses and the consumer
# proceeds with no dy mask — skipping degrades, numerics don't.
#
# A bounded ring (not a dict) so stale entries from completed traces are
# overwritten instead of accumulating; matching is by ``is``, so a stale
# entry can never alias a live cotangent.  Sized so every WG bitmap of a
# deep model's backward pass (vgg16: 13 convs + head) survives until the
# step-level gradient collective consults the registry AFTER the whole
# backward has run (sharding/collectives.psum_grads) — with the old size
# of 8 the early layers' entries were already evicted by then.
_GRAD_BITMAP_RING_SIZE = 64
_GRAD_BITMAPS: list = []

# Fault-injection tap (repro/runtime/faults.py): an installed hook may veto
# a registration (the "registry drop" fault class) so the chaos harness can
# prove a missed hand-off is detected (``registry:miss`` counter) and
# survived (a miss degrades to no mask, never to wrong numerics).
_REGISTER_HOOK = None


def set_register_hook(fn):
    """Install (or, with None, remove) the registry fault hook; returns the
    previous hook.  The hook receives ``(obj, bitmap, gran)`` and returns
    False to drop the registration."""
    global _REGISTER_HOOK
    prev, _REGISTER_HOOK = _REGISTER_HOOK, fn
    return prev


def register_grad_bitmap(obj, bitmap: Optional[jnp.ndarray],
                         gran: Tuple[int, int]) -> None:
    """Record ``bitmap`` (granularity ``gran``) as describing the 2-D view
    of cotangent ``obj``.  No-op when ``bitmap`` is None."""
    if bitmap is None:
        return
    if _REGISTER_HOOK is not None \
            and _REGISTER_HOOK(obj, bitmap, gran) is False:
        return
    _GRAD_BITMAPS.append((obj, bitmap, gran))
    if len(_GRAD_BITMAPS) > _GRAD_BITMAP_RING_SIZE:
        del _GRAD_BITMAPS[0]


def lookup_grad_bitmap(obj, *, peek: bool = False):
    """The ``(bitmap, gran)`` a producer registered for this exact
    cotangent object, or None.  Most-recent-first: backward order is
    loss → input, so the producer's entry is the freshest.

    Hits and misses are counted (``registry:hit`` / ``registry:miss``) so
    the runtime guard can tell routine misses (the loss cotangent has no
    producer) from a drop storm — the fault class where emitted bitmaps
    stop reaching their consumers.  ``peek=True`` consults without
    counting: the gradient collective probes EVERY pytree leaf (biases,
    embeddings, scalars) and those structural misses would swamp the
    guard's ``registry:miss`` delta budget with noise."""
    for entry, bitmap, gran in reversed(_GRAD_BITMAPS):
        if entry is obj:
            if not peek:
                stats.record("registry:hit")
            return bitmap, gran
    if not peek:
        stats.record("registry:miss")
    return None


# ---------------------------------------------------------------------------
# Bitmap computation — the ONLY functions that scan tensor-sized data.
# ---------------------------------------------------------------------------

def scan_bitmap(x2d: jnp.ndarray, gran: Tuple[int, int],
                *, kind: str = "act", impl: str = "xla_ref",
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """One counted dense scan -> fine bitmap (used for signed data — raw
    inputs, incoming gradients — where no fused encode produced one).

    ``impl="pallas"`` routes through the TPU-native ``kernels.bitmap_scan``
    kernel (counted as ``scan_pallas:<kind>``); the default stays the XLA
    reference (counted as ``scan:<kind>``) for the xla_ref policy."""
    if impl == "pallas":
        from repro.kernels import ops as kops  # local: avoids import cycle
        return kops.bitmap_scan(x2d, block=gran, kind=kind,
                                interpret=interpret)
    gr, gc = gran
    m, n = x2d.shape
    mp, np_ = _ceil_div(m, gr) * gr, _ceil_div(n, gc) * gc
    stats.record(f"scan:{kind}")
    with stats.lifecycle_scope("scan", kind):
        if mp != m or np_ != n:
            x2d = jnp.pad(x2d, ((0, mp - m), (0, np_ - n)))
        return kref.block_any_nonzero(x2d.astype(jnp.float32), gr, gc)
