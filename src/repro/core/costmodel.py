"""Analytical cost/energy model of the paper's accelerator (§5, Table 1).

The paper evaluates with a cycle-accurate simulator driven by real
activation/gradient traces.  We reproduce that methodology: this module is
the simulator (analytical, event-level for the WDU), and benchmarks/ feeds
it traces captured from real JAX training of the same five CNNs.

Hardware constants are the paper's Table 1 node: 256 PEs × 16 lanes at
667 MHz (4096 MACs/cycle = 8192 FLOPs/cycle ⇒ 5.46 TFLOP/s), 32 KB×4 SRAM
banks/PE (32 MB total), 16-ch DDR3-1600, H-tree broadcast @ 512 GB/s.

Modeled effects, mapped to paper sections:
  * element-granular skipping of FP-IN / BP-IN / BP-OUT / WG-IN  (§3)
  * lane occupancy for receptive fields CRS vs the 1024-entry PE capacity,
    with none / direct (power-of-2 replication) / hierarchical
    reconfiguration of the adder tree                            (§4.5)
  * synapse blocking for CRS > 1024 (K-blocking ceil waste)      (§4.4)
  * spatial load imbalance across the 16×16 PE-tile grid and the WDU
    redistribution policy (via core.workredist)                  (§4.6)
  * DRAM streaming overlap (compute/memory max, §6 "DRAM considerations")
  * energy: MAC + SRAM access + static node power × makespan
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from . import workredist
from .policy import SparsityPolicy


# ---------------------------------------------------------------------------
# Hardware description (paper Table 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HwConfig:
    tx: int = 16
    ty: int = 16
    lanes_per_pe: int = 16
    entries_per_lane_group: int = 32
    groups: int = 2
    freq_hz: float = 667e6
    bytes_per_value: int = 2                      # fp16/bf16
    dram_bw_bytes: float = 16 * 12.6e9            # 16× DDR3-1600
    e_mac_j: float = 10.56e-3 / (16 * 667e6)      # MAC block power / (units·f)
    e_sram_rd_j: float = 0.035e-9
    e_sram_wr_j: float = 0.040e-9
    node_power_w: float = 19.2

    @property
    def n_pes(self) -> int:
        return self.tx * self.ty

    @property
    def macs_per_cycle(self) -> int:
        return self.n_pes * self.lanes_per_pe

    @property
    def pe_capacity(self) -> int:                 # receptive-field entries/PE
        return self.lanes_per_pe * self.entries_per_lane_group * self.groups

    @property
    def peak_flops(self) -> float:
        return 2.0 * self.macs_per_cycle * self.freq_hz


DEFAULT_HW = HwConfig()


# ---------------------------------------------------------------------------
# Layer & trace description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One CONV (or FC, with H=W=U=V=1, R=S=1) layer's static shape.

    ``groups`` models grouped/depthwise convs (groups == c for depthwise):
    every output channel contracts over a C/G·R·S receptive field and every
    input channel receives from M/G·R·S weights, so all three phases' MAC
    counts — and the lane-occupancy receptive field ``crs`` — shrink by G.
    MobileNet's dw layers are thereby *modeled* rather than approximated as
    full convs (which overcounted their work C-fold)."""
    name: str
    c: int
    h: int
    w: int
    m: int
    r: int
    s: int
    stride: int = 1
    groups: int = 1
    has_bn: bool = False          # BN between this CONV and its ReLU
    input_is_relu: bool = True    # producer of our input is a ReLU (enables
                                  # FP-IN and BP-OUT sparsity)
    output_feeds_relu: bool = True  # our output goes through (BN+)ReLU
    batch: int = 16

    @property
    def u(self) -> int:
        return max(1, math.ceil(self.h / self.stride))

    @property
    def v(self) -> int:
        return max(1, math.ceil(self.w / self.stride))

    @property
    def crs(self) -> int:
        """Per-output receptive field: C/G·R·S (the PE lane-packing unit)."""
        return self.c * self.r * self.s // self.groups

    @property
    def mrs(self) -> int:
        """Per-input BP receptive field: M/G·R·S."""
        return self.m * self.r * self.s // self.groups

    def macs_fp(self) -> float:
        return float(self.batch * self.m * self.u * self.v * self.crs)

    def macs_bp(self) -> float:   # dX: [M,U,V] -> [C,H,W] through RS×M/G
        return float(self.batch * self.c * self.h * self.w * self.mrs)

    def macs_wg(self) -> float:   # dW: M·(C/G)·R·S outputs × U·V·batch accum
        return float(self.batch * self.m * self.crs * self.u * self.v)


@dataclasses.dataclass
class LayerTrace:
    """Measured densities (1 - sparsity) from real tensors, plus the spatial
    active-output maps used for tile-imbalance modeling.

    density ∈ [0, 1]; None ⇒ dense (1.0)."""
    x_density: float = 1.0            # input activation density (post-ReLU)
    g_in_density: float = 1.0         # incoming gradient density in BP
    out_mask_density: float = 1.0     # density of σ'(input) — BP-OUT skip list
    fp_active_map: Optional[np.ndarray] = None   # (U, V) active outputs FP
    bp_active_map: Optional[np.ndarray] = None   # (H, W) active outputs BP


# ---------------------------------------------------------------------------
# Lane-occupancy models (§4.4, §4.5 / Fig. 16)
# ---------------------------------------------------------------------------

def lane_utilization(crs: int, hw: HwConfig, mode: str = "hierarchical") -> float:
    """Fraction of MAC lanes doing useful work for receptive-field size CRS.

    mode ∈ {"none", "direct", "hierarchical"}:
      none         — one output at a time, occupying ceil(CRS/32) lanes
      direct       — replicate to the nearest power-of-2 lane count
      hierarchical — recursive alignment: near-full packing (paper §4.5)
    """
    cap = hw.pe_capacity  # 1024
    if crs >= cap:
        # §4.4 synapse blocking: ceil waste on the last K-block only.
        return crs / (math.ceil(crs / cap) * cap)
    # lane capacity spans both double-buffer groups (paper: 3x3x64=576
    # occupies 9/16 lanes ⇒ 64 entries per lane)
    entries = hw.entries_per_lane_group * hw.groups
    occ = math.ceil(crs / entries)              # lanes needed per output
    lanes = hw.lanes_per_pe
    if mode == "none":
        return occ / lanes * (crs / (occ * entries))
    if mode == "direct":
        aligned = 1 << math.ceil(math.log2(occ)) if occ > 1 else 1
        outputs = lanes // aligned
        return (occ * outputs) / lanes * (crs / (occ * entries))
    # hierarchical: schedule the binary decomposition of occ across
    # iterations; residual misalignment is one partial lane-group.
    packing = 0.98
    return packing * (crs / (occ * entries)) if occ * entries > 0 else packing


# ---------------------------------------------------------------------------
# Per-layer, per-phase cost
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PhaseCost:
    macs_dense: float
    macs_effective: float
    compute_cycles: float
    dram_bytes: float
    mem_cycles: float
    cycles: float                 # max(compute, mem) — streaming overlap
    energy_j: float
    wdu: Optional[workredist.WDUResult] = None

    @property
    def time_s(self) -> float:
        return self.cycles / DEFAULT_HW.freq_hz


def _phase_cost(
    macs_dense: float,
    density_product: float,
    crs: int,
    dram_bytes: float,
    hw: HwConfig,
    *,
    tile_work: Optional[np.ndarray] = None,
    work_redistribution: bool = False,
    reconfig_mode: str = "hierarchical",
) -> PhaseCost:
    macs_eff = macs_dense * density_product
    util = lane_utilization(crs, hw, reconfig_mode)
    util = max(util, 1e-3)
    ideal_cycles = macs_eff / (hw.macs_per_cycle * util)
    wdu = None
    if tile_work is not None and tile_work.sum() > 0:
        # tile_work is in MACs; scale to the same effective density and
        # per-PE throughput (lanes × util MACs/cycle).
        scale = macs_eff / max(tile_work.sum(), 1e-9)
        per_tile_cycles = tile_work * scale / (hw.lanes_per_pe * util)
        wdu = workredist.simulate(per_tile_cycles, redistribute=work_redistribution)
        compute_cycles = wdu.makespan
    else:
        compute_cycles = ideal_cycles
    mem_cycles = dram_bytes / hw.dram_bw_bytes * hw.freq_hz
    cycles = max(compute_cycles, mem_cycles)
    # energy: 2 SRAM reads (neuron+synapse) + amortized writes per MAC
    e = (
        macs_eff * (hw.e_mac_j + 2 * hw.e_sram_rd_j + 0.1 * hw.e_sram_wr_j)
        + hw.node_power_w * 0.3 * (cycles / hw.freq_hz)  # static fraction
    )
    return PhaseCost(
        macs_dense=macs_dense,
        macs_effective=macs_eff,
        compute_cycles=compute_cycles,
        dram_bytes=dram_bytes,
        mem_cycles=mem_cycles,
        cycles=cycles,
        energy_j=e,
        wdu=wdu,
    )


@dataclasses.dataclass
class LayerCost:
    fp: PhaseCost
    bp: PhaseCost
    wg: PhaseCost

    @property
    def total_cycles(self) -> float:
        return self.fp.cycles + self.bp.cycles + self.wg.cycles

    @property
    def total_energy(self) -> float:
        return self.fp.energy_j + self.bp.energy_j + self.wg.energy_j


def layer_cost(
    spec: ConvSpec,
    trace: LayerTrace,
    scenario: str,
    hw: HwConfig = DEFAULT_HW,
    reconfig_mode: str = "hierarchical",
) -> LayerCost:
    """Cost one CONV layer under a paper scenario: DC | IN | IN_OUT | IN_OUT_WR.

    Sparsity applicability rules (paper §2.1, §6):
      FP-IN  : input density counts iff the input is post-ReLU.
      BP-IN  : incoming gradient density counts iff OUR ReLU's gradient is
               not re-densified before reaching the GEMM — i.e. no BN
               between this CONV and its ReLU.  (trace.g_in_density already
               measures the tensor that actually arrives.)
      BP-OUT : σ'(input) density iff the producer of our input is a ReLU
               (not pool/input/concat-of-dense).
      WG-IN  : x density × gradient density.
    """
    assert scenario in ("DC", "IN", "IN_OUT", "IN_OUT_WR"), scenario
    use_in = scenario in ("IN", "IN_OUT", "IN_OUT_WR")
    use_out = scenario in ("IN_OUT", "IN_OUT_WR")
    use_wr = scenario == "IN_OUT_WR"

    x_d = trace.x_density if (use_in and spec.input_is_relu) else 1.0
    g_d = trace.g_in_density if use_in else 1.0
    o_d = trace.out_mask_density if (use_out and spec.input_is_relu) else 1.0

    bpv = hw.bytes_per_value
    w_bytes = spec.m * spec.crs * bpv
    fp_bytes = w_bytes + spec.batch * (spec.c * spec.h * spec.w +
                                       spec.m * spec.u * spec.v) * bpv
    bp_bytes = w_bytes + spec.batch * (spec.m * spec.u * spec.v +
                                       spec.c * spec.h * spec.w) * bpv
    wg_bytes = fp_bytes

    # Tile-imbalance only exists when skipping is on: under DC every tile
    # does identical dense work.  The maps encode per-output-location
    # relative work (nnz-driven), measured from real traces.
    tile_fp = tile_bp = None
    if trace.fp_active_map is not None and use_in and spec.input_is_relu:
        tile_fp = workredist.tile_work_from_mask(
            trace.fp_active_map, hw.tx, hw.ty, spec.crs * x_d)
    if trace.bp_active_map is not None and use_out and spec.input_is_relu:
        tile_bp = workredist.tile_work_from_mask(
            trace.bp_active_map, hw.tx, hw.ty, spec.mrs * g_d)

    fp = _phase_cost(spec.macs_fp(), x_d, spec.crs, fp_bytes, hw,
                     tile_work=tile_fp, work_redistribution=use_wr,
                     reconfig_mode=reconfig_mode)
    bp = _phase_cost(spec.macs_bp(), g_d * o_d, spec.mrs,
                     bp_bytes, hw, tile_work=tile_bp,
                     work_redistribution=use_wr, reconfig_mode=reconfig_mode)
    wg = _phase_cost(spec.macs_wg(), x_d * g_d, spec.u * spec.v * spec.batch,
                     wg_bytes, hw, work_redistribution=use_wr,
                     reconfig_mode=reconfig_mode)
    return LayerCost(fp=fp, bp=bp, wg=wg)


def network_cost(
    layers: List[ConvSpec],
    traces: List[LayerTrace],
    scenario: str,
    hw: HwConfig = DEFAULT_HW,
) -> Dict[str, float]:
    costs = [layer_cost(s, t, scenario, hw) for s, t in zip(layers, traces)]
    return {
        "fp_cycles": sum(c.fp.cycles for c in costs),
        "bp_cycles": sum(c.bp.cycles for c in costs),
        "wg_cycles": sum(c.wg.cycles for c in costs),
        "total_cycles": sum(c.total_cycles for c in costs),
        "total_energy_j": sum(c.total_energy for c in costs),
        "iteration_ms": sum(c.total_cycles for c in costs) / hw.freq_hz * 1e3,
    }
