"""The paper's fused CONV–ReLU unit, lowered to masked GEMMs via im2col.

The paper's accelerator executes CONV as GEMM over the receptive field
(K = C·R·S — its "synapse blocking at 1024" is K-blocking, §4.4).  We do the
same: im2col the operand, run the block-sparse GEMM kernels, fold back.

ONE engine, four public faces.  ``_conv_engine_fwd``/``_conv_engine_bwd``
is a single parameterized custom-VJP pair taking ``(fused_relu, groups)``;
every conv flavour is a thin wrapper over it:

  relu_conv            fused_relu=True,  groups=1   (the paper's unit)
  conv                 fused_relu=False, groups=1   (signed input: pool /
                                                     input-layer boundary)
  grouped variants     groups=G (C % G == 0, M % G == 0): per-group im2col
                       → ONE batched masked GEMM (G, ·, ·) per stage
  depthwise_relu_conv  groups=C — MobileNet's dw layers, full FP/BP/WG
                       sparsity treatment instead of a dense fallback

All three stages realize the same skipping opportunities as
core.sparse_linear:
  FP  input sparsity of relu(x_pre) patches,
  BP  output sparsity from σ'(x_pre) (survives BatchNorm *after* the conv),
      + input sparsity of the incoming gradient patches,
  WG  input sparsity on both operands.

Sparsity metadata lifecycle: the forward pass runs the fused
``kernels.relu_encode`` over the activation's (N·H·W, C) view ONCE, at
per-pixel row granularity so the bitmap stays spatially addressable.  Every
other mask is then *derived* from it without rescanning tensor-sized data:

  * the backward out_mask is the same bitmap re-tiled to (bm, bn) — the
    paper's FP/BP footprint identity;
  * patch (im2col) operand masks — FP a_mask and the WG Xᵀ mask — come from
    running ``_im2col`` on the BITMAP itself (a gather over an array C/gc×
    smaller than the activation), then coarsening.  This is exact, because
    an im2col'd any-nonzero cell equals the any-nonzero of the im2col'd
    data (same gather, zero padding on both sides);
  * the incoming gradient is scanned at most once per step; its dilated/
    im2col'd mask (dX GEMM) and its (bk, bn) re-tiling (dW GEMM) are both
    derived from that single fine bitmap.

Grouped convs reuse the SAME derivations: the channel granularity divides
C//G (see ``conv_channel_granularity``), so per-group masks are pure
reshapes of the one bitmap's columns — group g's slice of the im2col'd
bitmap IS the bitmap of group g's im2col'd data.  Per-group GEMM tiles
come from ``policy.gemm_spec(dims=..., grans=...)`` (the
``grouped_gemm_block`` degenerate-tile rule): depthwise K-dims are tiny
(R·S·1), so edges degenerate to the granularity-rounded dims instead of
padding a 128-block that could never mask anything.  Every stage's GEMM —
dense or grouped — is one ``kernels.ops.sparse_gemm`` dispatch on that
spec (see docs/gemm_api.md).

Exactness vs dense autodiff is asserted in tests for stride ∈ {1, 2},
padding ∈ {SAME, VALID} and groups ∈ {1, 2, C}; threaded-vs-rescanned mask
equality is property-tested in tests/test_bitmap_threading.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import stats
from repro.kernels.shapes import block_bitmap as _bitmap_padded
from .policy import SparsityPolicy
from .sparse_linear import _mm, _needs_act_bitmap, _needs_grad_bitmap
from .sparse_tensor import (
    SparseTensor,
    coarsen_bitmap,
    conv_channel_granularity,
    lookup_grad_bitmap,
    register_grad_bitmap,
    scan_bitmap,
)


def _pad_amounts(h: int, r: int, stride: int, padding: str) -> Tuple[int, int]:
    if padding == "VALID":
        return 0, 0
    out = -(-h // stride)  # ceil
    total = max((out - 1) * stride + r - h, 0)
    return total // 2, total - total // 2


def conv_out_size(h: int, r: int, stride: int, padding: str) -> int:
    lo, hi = _pad_amounts(h, r, stride, padding)
    return (h + lo + hi - r) // stride + 1


def _im2col(x: jnp.ndarray, r: int, s: int, stride: int,
            pad: Tuple[int, int, int, int]) -> jnp.ndarray:
    """x: (N,H,W,C) -> (N, U, V, R*S*C) patches, (r, s, c)-ordered."""
    n, h, w, c = x.shape
    plo_h, phi_h, plo_w, phi_w = pad
    xp = jnp.pad(x, ((0, 0), (plo_h, phi_h), (plo_w, phi_w), (0, 0)))
    hp, wp = h + plo_h + phi_h, w + plo_w + phi_w
    u = (hp - r) // stride + 1
    v = (wp - s) // stride + 1
    cols = []
    for dr in range(r):
        for ds in range(s):
            cols.append(
                jax.lax.slice(
                    xp, (0, dr, ds, 0),
                    (n, dr + (u - 1) * stride + 1, ds + (v - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    patches = jnp.stack(cols, axis=3)          # (N,U,V,R*S,C)
    return patches.reshape(n, u, v, r * s * c)


def _dilate_hw(x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Insert stride-1 zeros between spatial elements (for grad-input)."""
    if stride == 1:
        return x
    n, h, w, c = x.shape
    out = jnp.zeros((n, (h - 1) * stride + 1, (w - 1) * stride + 1, c), x.dtype)
    return out.at[:, ::stride, ::stride, :].set(x)


# ---------------------------------------------------------------------------
# Group splitting — pure reshapes; the (tap, channel)-minor K ordering means
# group g's columns are contiguous per tap, so one transpose regroups a full
# patch matrix (data OR bitmap) into the (G, ·, ·) batched-GEMM layout.
# ---------------------------------------------------------------------------

def _group_patches(pm2: jnp.ndarray, taps: int, groups: int) -> jnp.ndarray:
    """(T, taps*C') patch matrix -> (G, T, taps*C'/G), per-group K slices.

    Works identically on data (C' = C) and fine bitmaps (C' = C/gc): the
    granularity divides C//G, so cells nest inside groups."""
    t, k = pm2.shape
    cg = k // taps // groups
    return pm2.reshape(t, taps, groups, cg).transpose(2, 0, 1, 3) \
        .reshape(groups, t, taps * cg)


def _group_cols(x2: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(T, C') channel-minor matrix -> (G, T, C'/G)."""
    t, c = x2.shape
    return x2.reshape(t, groups, c // groups).transpose(1, 0, 2)


def _ungroup_cols(x3: jnp.ndarray) -> jnp.ndarray:
    """(G, T, C/G) -> (T, C), inverse of ``_group_cols``."""
    g, t, cg = x3.shape
    return x3.transpose(1, 0, 2).reshape(t, g * cg)


def _group_weights(w: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(R, S, C//G, M) grouped-HWIO weights -> (G, R·S·C//G, M//G).

    Follows lax.conv_general_dilated's feature_group_count convention:
    output block g (channels [g·M/G, (g+1)·M/G)) reads input group g."""
    r, s, cg, m = w.shape
    mg = m // groups
    return w.reshape(r * s * cg, groups, mg).transpose(1, 0, 2)


def _group_weights_bwd(w: jnp.ndarray, groups: int) -> jnp.ndarray:
    """Per-group dX weights: (R, S, C//G, M) -> (G, R·S·M//G, C//G),
    spatially flipped and (r, s, m, c)-ordered to match gradient patches."""
    r, s, cg, m = w.shape
    mg = m // groups
    wf = jnp.flip(w, axis=(0, 1)).reshape(r, s, cg, groups, mg)
    return wf.transpose(3, 0, 1, 4, 2).reshape(groups, r * s * mg, cg)


# ---------------------------------------------------------------------------
# Bitmap derivation (no tensor-sized scans past this line)
# ---------------------------------------------------------------------------

def _patch_bitmap(st: SparseTensor, spatial: Tuple[int, int, int, int],
                  r: int, s: int, stride: int,
                  pad: Tuple[int, int, int, int]) -> SparseTensor:
    """im2col in bitmap space: (N·H·W, C/gc) fine bitmap -> fine bitmap of
    the patch matrix (N·U·V, R·S·C/gc), exactly matching a fresh scan of
    ``_im2col(data)``.  Pure gather on the bitmap — the activation is not
    touched."""
    n, h, w, c = spatial
    gc = st.gran[1]
    with stats.lifecycle_scope("derive", "im2col"):
        fb4 = st.bitmap.reshape(n, h, w, c // gc)
        pb = _im2col(fb4, r, s, stride, pad)   # (N, U, V, R*S*C/gc)
        u, v = pb.shape[1], pb.shape[2]
        return SparseTensor(None, pb.reshape(n * u * v, -1), (1, gc))


def _encode_conv_act(x_pre: jnp.ndarray, policy: SparsityPolicy,
                     gc: int) -> Tuple[jnp.ndarray, SparseTensor]:
    """(relu(x_pre), SparseTensor over the (N·H·W, C) view) — ONE fused
    encode (pallas) or one counted scan (xla_ref) per activation per step."""
    n, h, w, c = x_pre.shape
    x2d = x_pre.reshape(n * h * w, c)
    if policy.kernel_impl == "pallas":
        y2d, fb = kops.relu_encode(x2d, block=(1, gc),
                                   interpret=policy.interpret)
        x = y2d.reshape(n, h, w, c)
    else:
        x = jnp.maximum(x_pre, jnp.zeros((), x_pre.dtype))
        fb = scan_bitmap(x.reshape(n * h * w, c), (1, gc), kind="act")
    return x, SparseTensor(x_pre, fb, (1, gc))


def _grad_sparse_tensor(dy, dy32: jnp.ndarray, policy: SparsityPolicy,
                        m: int, groups: int = 1) -> SparseTensor:
    """Fine bitmap of the incoming gradient, recovered from the PRODUCING
    dX GEMM's writeback-emitted bitmap (registered against the exact
    cotangent object ``dy``) — never a rescan.  A miss (cotangent straight
    from the loss / a pool / BatchNorm, or an unusable granularity)
    degrades to no dy mask: skipping lost, numerics untouched."""
    if not _needs_grad_bitmap(policy):
        return SparseTensor(dy32, None, None)
    hit = lookup_grad_bitmap(dy)
    if hit is None:
        return SparseTensor(dy32, None, None)
    fb, (gr, gcg) = hit
    bm, bk, bn = policy.block
    # The conv derivations need per-pixel rows (the bitmap is reshaped to
    # the (N, U, V, M/gc) spatial view), channel cells nesting inside
    # groups, and a channel granularity every derived mask edge divides.
    if (gr != 1 or m % gcg or (m // gcg) % groups
            or bk % gcg or bn % gcg):
        return SparseTensor(dy32, None, None)
    return SparseTensor(dy32, fb, (1, gcg))


# ---------------------------------------------------------------------------
# The engine — one forward/backward pair for every conv flavour
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _conv_engine(x: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str,
                 policy: SparsityPolicy, fused_relu: bool, groups: int):
    """y = conv2d(relu(x) if fused_relu else x, w, groups).

    x: (N,H,W,C); w: (R,S,C//G,M) — lax grouped-HWIO layout."""
    y, _ = _conv_engine_fwd(x, w, stride, padding, policy, fused_relu, groups)
    return y


def _conv_engine_fwd(x_in, w, stride, padding, policy: SparsityPolicy,
                     fused_relu: bool, groups: int):
    n, h, wd, c = x_in.shape
    r, s, cg_w, m = w.shape
    assert c % groups == 0 and m % groups == 0 and cg_w == c // groups, \
        (x_in.shape, w.shape, groups)
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    pad4 = (plh[0], plh[1], plw[0], plw[1])

    # --- activation + its once-computed bitmap ---
    if fused_relu:
        if _needs_act_bitmap(policy):
            gc = conv_channel_granularity(c, policy.block, groups)
            x, st = _encode_conv_act(x_in, policy, gc)
        else:
            x = jnp.maximum(x_in, jnp.zeros((), x_in.dtype))
            st = SparseTensor(x_in, None, None)
    else:
        # Signed input (pool / input-layer boundary): no fused encode, so a
        # bitmap costs a standalone scan — opt-in via scan_signed_inputs
        # (off by default: raw inputs are near-dense, and with dy bitmaps
        # emitted by the GEMM epilogue the hot path then launches zero
        # scan_pallas:* passes).
        x = x_in
        st = SparseTensor(x, None, None)
        if policy.scan_signed_inputs and policy.kernel_impl == "pallas" and (
                policy.use_input_sparsity_fp or policy.use_input_sparsity_bp):
            gc = conv_channel_granularity(c, policy.block, groups)
            st = SparseTensor(
                x,
                scan_bitmap(x.reshape(n * h * wd, c), (1, gc), kind="act",
                            impl=policy.kernel_impl,
                            interpret=policy.interpret),
                (1, gc))

    # --- FP GEMM: patches @ weights ---
    patches = _im2col(x, r, s, stride, pad4)
    u, v = patches.shape[1], patches.shape[2]
    t = n * u * v
    pm = patches.reshape(t, r * s * c)
    want_a_mask = (policy.use_input_sparsity_fp
                   and policy.kernel_impl == "pallas"
                   and st.bitmap is not None)
    if groups == 1:
        a_mask = None
        if want_a_mask:
            bm, bk, bn = policy.block
            a_mask = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4) \
                .mask_for((bm, bk))
        y = _mm(pm, w.reshape(r * s * c, m), None, a_mask, None, policy,
                x_in.dtype)
    else:
        cg, mg = c // groups, m // groups
        gc = st.gran[1] if st.gran else 1
        spec = policy.gemm_spec(groups=groups, dims=(t, r * s * cg, mg),
                                grans=(1, gc, 1))
        blk = spec.block
        a_mask = None
        if want_a_mask and r * s * cg >= policy.grouped_sparsity_min_k:
            pb = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4)
            pbg = _group_patches(pb.bitmap, r * s, groups)
            a_mask = coarsen_bitmap(pbg, (1, gc), (blk[0], blk[1]))
        yg = _mm(_group_patches(pm, r * s, groups), _group_weights(w, groups),
                 None, a_mask, None, policy, x_in.dtype, spec=spec)
        y = _ungroup_cols(yg)
    return y.reshape(n, u, v, m), (st, w)


def _conv_engine_bwd(stride, padding, policy: SparsityPolicy,
                     fused_relu: bool, groups: int, res, dy):
    st, w = res
    n, h, wd, c = st.data.shape
    r, s, _, m = w.shape
    u, v = dy.shape[1], dy.shape[2]
    bm, bk, bn = policy.block
    if fused_relu:
        x_pre = st.data
        relu_mask = (x_pre > 0)
        x = jnp.where(relu_mask, x_pre, jnp.zeros((), x_pre.dtype))
        out_dtype = x_pre.dtype
    else:
        x = st.data
        relu_mask = None
        out_dtype = x.dtype
    dy32 = dy.astype(jnp.float32)
    st_dy = _grad_sparse_tensor(dy, dy32, policy, m, groups)
    t = n * u * v
    cg, mg = c // groups, m // groups
    gc = st.gran[1] if st.gran else 1
    gcg = st_dy.gran[1] if st_dy.gran else 1

    # ---- dX: full-correlation of dilated dy with flipped w; for the fused
    # unit the σ' Hadamard rides the kernel epilogue → OUTPUT sparsity on
    # the (N·H·W, C) GEMM. ----
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    dyd = _dilate_hw(dy32, stride)
    hd, wdd = dyd.shape[1], dyd.shape[2]
    # output spatial size must equal (h, wd):  pad_lo = r-1-fwd_pad_lo
    pg_h_lo = r - 1 - plh[0]
    pg_h_hi = h - (hd + pg_h_lo - r + 1)
    pg_w_lo = s - 1 - plw[0]
    pg_w_hi = wd - (wdd + pg_w_lo - s + 1)
    gpad4 = (pg_h_lo, pg_h_hi, pg_w_lo, pg_w_hi)
    gpatches = _im2col(dyd, r, s, 1, gpad4)
    gm2 = gpatches.reshape(n * h * wd, r * s * m)
    # out_mask: the forward ReLU bitmap, re-tiled (footprint(σ') ==
    # footprint(relu) — paper §3.2).  Zero recompute.  Plain convs have no
    # σ' ⇒ no output sparsity (Fig. 11 discussion).
    use_out = fused_relu and policy.use_output_sparsity \
        and st.bitmap is not None
    # gradient-patch mask: the dy bitmap dilated and im2col'd in bitmap
    # space — mirrors exactly what the data underwent.
    gpb2 = None
    if st_dy.bitmap is not None:
        with stats.lifecycle_scope("derive", "grad_patches"):
            gfb4 = st_dy.bitmap.reshape(n, u, v, m // gcg)
            gpb = _im2col(_dilate_hw(gfb4, stride), r, s, 1, gpad4)
            gpb2 = gpb.reshape(n * h * wd, -1)
    mask2d = relu_mask.reshape(n * h * wd, c).astype(jnp.float32) \
        if fused_relu else None

    # This dX GEMM produces the layer BELOW's dy: its writeback epilogue
    # emits that dy's fine bitmap (per-pixel rows, channel granularity of
    # THIS layer's input) and registers it against the returned cotangent.
    emit_gc = conv_channel_granularity(c, policy.block, groups) \
        if _needs_grad_bitmap(policy) else None

    if groups == 1:
        wt = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2) \
            .reshape(r * s * m, c)
        out_mask = st.mask_for((bm, bn)) if use_out else None
        g_mask = None
        if gpb2 is not None:
            g_mask = coarsen_bitmap(gpb2, (1, gcg), (bm, bk))
        res_dx = _mm(gm2, wt.astype(jnp.float32), out_mask, g_mask, None,
                     policy, out_dtype, epilogue=mask2d,
                     emit_gran=None if emit_gc is None else (1, emit_gc))
        dx2, dx_bits = res_dx if emit_gc is not None else (res_dx, None)
        dx = dx2.reshape(n, h, wd, c)
        if emit_gc is not None:
            register_grad_bitmap(dx, dx_bits, (1, emit_gc))
    else:
        spec = policy.gemm_spec(groups=groups,
                                dims=(n * h * wd, r * s * mg, cg),
                                grans=(1, gcg, gc))
        blk = spec.block
        out_mask = None
        if use_out:
            out_mask = coarsen_bitmap(_group_cols(st.bitmap, groups),
                                      (1, gc), (blk[0], blk[2]))
        g_mask = None
        if gpb2 is not None and r * s * mg >= policy.grouped_sparsity_min_k:
            g_mask = coarsen_bitmap(_group_patches(gpb2, r * s, groups),
                                    (1, gcg), (blk[0], blk[1]))
        epi = _group_cols(mask2d, groups) if mask2d is not None else None
        res_dx = _mm(_group_patches(gm2, r * s, groups),
                     _group_weights_bwd(w, groups).astype(jnp.float32),
                     out_mask, g_mask, None, policy, out_dtype,
                     epilogue=epi, spec=spec,
                     emit_gran=None if emit_gc is None else (1, emit_gc))
        dxg, dxg_bits = res_dx if emit_gc is not None else (res_dx, None)
        dx = _ungroup_cols(dxg).reshape(n, h, wd, c)
        if emit_gc is not None and dxg_bits is not None:
            # Per-group bits columns regroup to the full channel axis the
            # same way the data does (cells nest inside groups: gc | C/G).
            register_grad_bitmap(dx, _ungroup_cols(dxg_bits), (1, emit_gc))

    # ---- dW = patches(x)ᵀ @ dy — WG stage, input sparsity both sides ----
    pad4 = (plh[0], plh[1], plw[0], plw[1])
    patches = _im2col(x, r, s, stride, pad4)
    pm = patches.reshape(t, r * s * c).astype(jnp.float32)
    dym = dy32.reshape(t, m)
    want_pt_mask = _needs_grad_bitmap(policy) and st.bitmap is not None
    if groups == 1:
        pt_mask = None
        if want_pt_mask:
            # Xᵀ patch mask: forward bitmap -> patch bitmap -> block transp.
            pt_mask = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4) \
                .t_mask_for((bm, bk))
        dym_mask = st_dy.mask_for((bk, bn))
        dw = _mm(pm.T, dym, None, pt_mask, dym_mask, policy, jnp.float32)
        dw = dw.reshape(r, s, c, m)
    else:
        spec = policy.gemm_spec(groups=groups, dims=(r * s * cg, t, mg),
                                grans=(gc, 1, gcg))
        blk = spec.block
        pt_mask = None
        if want_pt_mask:
            pb = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4)
            pbg = _group_patches(pb.bitmap, r * s, groups)
            pt_mask = coarsen_bitmap(pbg.transpose(0, 2, 1), (gc, 1),
                                     (blk[0], blk[1]))
        dym_mask = None
        if st_dy.bitmap is not None:
            dym_mask = coarsen_bitmap(_group_cols(st_dy.bitmap, groups),
                                      (1, gcg), (blk[1], blk[2]))
        dwg = _mm(_group_patches(pm, r * s, groups).transpose(0, 2, 1),
                  _group_cols(dym, groups), None, pt_mask, dym_mask, policy,
                  jnp.float32, spec=spec)
        # (G, R·S·C//G, M//G) -> (R, S, C//G, M) group-major output channels
        dw = dwg.transpose(1, 0, 2).reshape(r, s, cg, m)
    return dx, dw.astype(w.dtype)


_conv_engine.defvjp(_conv_engine_fwd, _conv_engine_bwd)


# ---------------------------------------------------------------------------
# Public wrappers — thin faces over the one engine
# ---------------------------------------------------------------------------

def relu_conv(x_pre: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str,
              policy: SparsityPolicy, groups: int = 1):
    """y = conv2d(relu(x_pre), w). x_pre: (N,H,W,C); w: (R,S,C//G,M)."""
    return _conv_engine(x_pre, w, stride, padding, policy, True, groups)


def conv(x: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str,
         policy: SparsityPolicy, groups: int = 1):
    """Plain conv2d (no fused ReLU): FP/BP input sparsity only.

    Used at MaxPool→CONV and input-layer boundaries where the paper notes
    output sparsity is not applicable (Fig. 11 discussion).  The input's
    nonzero bitmap is still computed only once (one counted scan — x may be
    signed, so the fused ReLU encode does not apply) and threaded to the
    forward operand mask and the WG transposed mask.
    """
    return _conv_engine(x, w, stride, padding, policy, False, groups)


def depthwise_relu_conv(x_pre: jnp.ndarray, w: jnp.ndarray, stride: int,
                        padding: str, policy: SparsityPolicy):
    """Depthwise conv over relu(x_pre): groups == C, w: (R,S,1,C·mult).

    MobileNet's dw layers — each channel is its own group, so the engine
    runs C tiny masked GEMMs as one batched launch with degenerate block
    shapes (K = R·S), and the producer's fused-encode bitmap drives all
    three stages exactly as for the dense convs."""
    return _conv_engine(x_pre, w, stride, padding, policy, True,
                        x_pre.shape[-1])


def depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, stride: int,
                   padding: str, policy: SparsityPolicy):
    """Depthwise conv over signed x (no fused ReLU): groups == C."""
    return _conv_engine(x, w, stride, padding, policy, False, x.shape[-1])


# Back-compat aliases used by tests/benchmarks that reach for the raw pair.
_relu_conv_fwd = functools.partial(_conv_engine_fwd, fused_relu=True,
                                   groups=1)
_conv_fwd = functools.partial(_conv_engine_fwd, fused_relu=False, groups=1)
