"""The paper's fused CONV–ReLU unit, lowered to masked GEMMs via im2col.

The paper's accelerator executes CONV as GEMM over the receptive field
(K = C·R·S — its "synapse blocking at 1024" is K-blocking, §4.4).  We do the
same: im2col the operand, run the block-sparse GEMM kernels, fold back.

``relu_conv(x_pre, w)`` = conv2d(relu(x_pre), w), NHWC / RSCM layouts,
with the same three skipping opportunities as core.sparse_linear:
  FP  input sparsity of relu(x_pre) patches,
  BP  output sparsity from σ'(x_pre) (survives BatchNorm *after* the conv),
      + input sparsity of the incoming gradient patches,
  WG  input sparsity on both operands.

Exactness vs dense autodiff is asserted in tests for stride ∈ {1, 2} and
padding ∈ {SAME, VALID}.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .policy import SparsityPolicy
from .sparse_linear import _bitmap_padded, _mm


def _pad_amounts(h: int, r: int, stride: int, padding: str) -> Tuple[int, int]:
    if padding == "VALID":
        return 0, 0
    out = -(-h // stride)  # ceil
    total = max((out - 1) * stride + r - h, 0)
    return total // 2, total - total // 2


def conv_out_size(h: int, r: int, stride: int, padding: str) -> int:
    lo, hi = _pad_amounts(h, r, stride, padding)
    return (h + lo + hi - r) // stride + 1


def _im2col(x: jnp.ndarray, r: int, s: int, stride: int,
            pad: Tuple[int, int, int, int]) -> jnp.ndarray:
    """x: (N,H,W,C) -> (N, U, V, R*S*C) patches, (r, s, c)-ordered."""
    n, h, w, c = x.shape
    plo_h, phi_h, plo_w, phi_w = pad
    xp = jnp.pad(x, ((0, 0), (plo_h, phi_h), (plo_w, phi_w), (0, 0)))
    hp, wp = h + plo_h + phi_h, w + plo_w + phi_w
    u = (hp - r) // stride + 1
    v = (wp - s) // stride + 1
    cols = []
    for dr in range(r):
        for ds in range(s):
            cols.append(
                jax.lax.slice(
                    xp, (0, dr, ds, 0),
                    (n, dr + (u - 1) * stride + 1, ds + (v - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    patches = jnp.stack(cols, axis=3)          # (N,U,V,R*S,C)
    return patches.reshape(n, u, v, r * s * c)


def _dilate_hw(x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Insert stride-1 zeros between spatial elements (for grad-input)."""
    if stride == 1:
        return x
    n, h, w, c = x.shape
    out = jnp.zeros((n, (h - 1) * stride + 1, (w - 1) * stride + 1, c), x.dtype)
    return out.at[:, ::stride, ::stride, :].set(x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def relu_conv(x_pre: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str,
              policy: SparsityPolicy):
    """y = conv2d(relu(x_pre), w). x_pre: (N,H,W,C); w: (R,S,C,M)."""
    y, _ = _relu_conv_fwd(x_pre, w, stride, padding, policy)
    return y


def _relu_conv_fwd(x_pre, w, stride, padding, policy: SparsityPolicy):
    x = jnp.maximum(x_pre, jnp.zeros((), x_pre.dtype))
    n, h, wd, c = x.shape
    r, s, _, m = w.shape
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    patches = _im2col(x, r, s, stride, (plh[0], plh[1], plw[0], plw[1]))
    u, v = patches.shape[1], patches.shape[2]
    pm = patches.reshape(n * u * v, r * s * c)
    wm = w.reshape(r * s * c, m)
    bm, bk, bn = policy.block
    a_mask = None
    if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas":
        a_mask = _bitmap_padded(pm.astype(jnp.float32), bm, bk)
    y = _mm(pm, wm, None, a_mask, None, policy, x_pre.dtype)
    return y.reshape(n, u, v, m), (x_pre, w)


def _relu_conv_bwd(stride, padding, policy: SparsityPolicy, res, dy):
    x_pre, w = res
    n, h, wd, c = x_pre.shape
    r, s, _, m = w.shape
    u, v = dy.shape[1], dy.shape[2]
    mask = (x_pre > 0)
    x = jnp.where(mask, x_pre, jnp.zeros((), x_pre.dtype))
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)

    # ---- dx_pre: full-correlation of dilated dy with flipped w, fused with
    # the σ' Hadamard → OUTPUT sparsity on the (N·H·W, C) GEMM. ----
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    dyd = _dilate_hw(dy32, stride)
    hd, wdd = dyd.shape[1], dyd.shape[2]
    # output spatial size must equal (h, wd):  pad_lo = r-1-fwd_pad_lo
    pg_h_lo = r - 1 - plh[0]
    pg_h_hi = h - (hd + pg_h_lo - r + 1) + 0  # solve for hi
    pg_w_lo = s - 1 - plw[0]
    pg_w_hi = wd - (wdd + pg_w_lo - s + 1)
    gpatches = _im2col(dyd, r, s, 1, (pg_h_lo, pg_h_hi, pg_w_lo, pg_w_hi))
    gm = gpatches.reshape(n * h * wd, r * s * m)
    # w flipped spatially, (r, s, m, c) ordering to match patch layout
    wt = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2).reshape(r * s * m, c)
    mask2d = mask.reshape(n * h * wd, c).astype(jnp.float32)
    out_mask = _bitmap_padded(mask2d, bm, bn) if policy.use_output_sparsity else None
    g_mask = _bitmap_padded(gm, bm, bk) if policy.use_input_sparsity_bp else None
    dx = _mm(gm, wt.astype(jnp.float32), out_mask, g_mask, None, policy, jnp.float32)
    dx_pre = (dx * mask2d).reshape(n, h, wd, c).astype(x_pre.dtype)

    # ---- dW = patches(x)ᵀ @ dy — WG stage, input sparsity both sides ----
    patches = _im2col(x, r, s, stride, (plh[0], plh[1], plw[0], plw[1]))
    pm = patches.reshape(n * u * v, r * s * c).astype(jnp.float32)
    dym = dy32.reshape(n * u * v, m)
    pt = pm.T
    pt_mask = _bitmap_padded(pt, bm, bk) if policy.use_input_sparsity_bp else None
    dym_mask = _bitmap_padded(dym, bk, bn) if policy.use_input_sparsity_bp else None
    dw = _mm(pt, dym, None, pt_mask, dym_mask, policy, jnp.float32)
    return dx_pre, dw.reshape(r, s, c, m).astype(w.dtype)


relu_conv.defvjp(_relu_conv_fwd, _relu_conv_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def conv(x: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str,
         policy: SparsityPolicy):
    """Plain conv2d (no fused ReLU): FP/BP input sparsity only.

    Used at MaxPool→CONV and input-layer boundaries where the paper notes
    output sparsity is not applicable (Fig. 11 discussion).
    """
    y, _ = _conv_fwd(x, w, stride, padding, policy)
    return y


def _conv_fwd(x, w, stride, padding, policy):
    # Reuse relu_conv's forward on a pre-activation that is already
    # non-negative?  No — x may be signed.  Run the same im2col GEMM without
    # the relu.
    n, h, wd, c = x.shape
    r, s, _, m = w.shape
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    patches = _im2col(x, r, s, stride, (plh[0], plh[1], plw[0], plw[1]))
    u, v = patches.shape[1], patches.shape[2]
    pm = patches.reshape(n * u * v, r * s * c)
    bm, bk, bn = policy.block
    a_mask = None
    if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas":
        a_mask = _bitmap_padded(pm.astype(jnp.float32), bm, bk)
    y = _mm(pm, w.reshape(r * s * c, m), None, a_mask, None, policy, x.dtype)
    return y.reshape(n, u, v, m), (x, w)


def _conv_bwd(stride, padding, policy, res, dy):
    x, w = res
    # Identical to relu_conv's backward with an all-ones mask and no output
    # sparsity; implement by temporarily treating x as its own "activation".
    n, h, wd, c = x.shape
    r, s, _, m = w.shape
    u, v = dy.shape[1], dy.shape[2]
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    dyd = _dilate_hw(dy32, stride)
    hd, wdd = dyd.shape[1], dyd.shape[2]
    pg_h_lo = r - 1 - plh[0]
    pg_h_hi = h - (hd + pg_h_lo - r + 1)
    pg_w_lo = s - 1 - plw[0]
    pg_w_hi = wd - (wdd + pg_w_lo - s + 1)
    gpatches = _im2col(dyd, r, s, 1, (pg_h_lo, pg_h_hi, pg_w_lo, pg_w_hi))
    gm = gpatches.reshape(n * h * wd, r * s * m)
    wt = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2).reshape(r * s * m, c)
    g_mask = _bitmap_padded(gm, bm, bk) if policy.use_input_sparsity_bp else None
    dx = _mm(gm, wt.astype(jnp.float32), None, g_mask, None, policy, x.dtype)
    dx = dx.reshape(n, h, wd, c)

    patches = _im2col(x, r, s, stride, (plh[0], plh[1], plw[0], plw[1]))
    pm = patches.reshape(n * u * v, r * s * c).astype(jnp.float32)
    dym = dy32.reshape(n * u * v, m)
    pt = pm.T
    pt_mask = _bitmap_padded(pt, bm, bk) if policy.use_input_sparsity_bp else None
    dym_mask = _bitmap_padded(dym, bk, bn) if policy.use_input_sparsity_bp else None
    dw = _mm(pt, dym, None, pt_mask, dym_mask, policy, jnp.float32)
    return dx, dw.reshape(r, s, c, m).astype(w.dtype)


conv.defvjp(_conv_fwd, _conv_bwd)
