"""The paper's fused CONV–ReLU unit, lowered to masked GEMMs via im2col.

The paper's accelerator executes CONV as GEMM over the receptive field
(K = C·R·S — its "synapse blocking at 1024" is K-blocking, §4.4).  We do the
same: im2col the operand, run the block-sparse GEMM kernels, fold back.

``relu_conv(x_pre, w)`` = conv2d(relu(x_pre), w), NHWC / RSCM layouts,
with the same three skipping opportunities as core.sparse_linear:
  FP  input sparsity of relu(x_pre) patches,
  BP  output sparsity from σ'(x_pre) (survives BatchNorm *after* the conv),
      + input sparsity of the incoming gradient patches,
  WG  input sparsity on both operands.

Sparsity metadata lifecycle: the forward pass runs the fused
``kernels.relu_encode`` over the activation's (N·H·W, C) view ONCE, at
per-pixel row granularity so the bitmap stays spatially addressable.  Every
other mask is then *derived* from it without rescanning tensor-sized data:

  * the backward out_mask is the same bitmap re-tiled to (bm, bn) — the
    paper's FP/BP footprint identity;
  * patch (im2col) operand masks — FP a_mask and the WG Xᵀ mask — come from
    running ``_im2col`` on the BITMAP itself (a gather over an array C/gc×
    smaller than the activation), then coarsening.  This is exact, because
    an im2col'd any-nonzero cell equals the any-nonzero of the im2col'd
    data (same gather, zero padding on both sides);
  * the incoming gradient is scanned at most once per step; its dilated/
    im2col'd mask (dX GEMM) and its (bk, bn) re-tiling (dW GEMM) are both
    derived from that single fine bitmap.

Exactness vs dense autodiff is asserted in tests for stride ∈ {1, 2} and
padding ∈ {SAME, VALID}; threaded-vs-rescanned mask equality is property-
tested in tests/test_bitmap_threading.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from .policy import SparsityPolicy
from .sparse_linear import (
    _bitmap_padded, _mm, _needs_act_bitmap, _needs_grad_bitmap,
)
from .sparse_tensor import (
    SparseTensor, coarsen_bitmap, conv_channel_granularity, scan_bitmap,
)


def _pad_amounts(h: int, r: int, stride: int, padding: str) -> Tuple[int, int]:
    if padding == "VALID":
        return 0, 0
    out = -(-h // stride)  # ceil
    total = max((out - 1) * stride + r - h, 0)
    return total // 2, total - total // 2


def conv_out_size(h: int, r: int, stride: int, padding: str) -> int:
    lo, hi = _pad_amounts(h, r, stride, padding)
    return (h + lo + hi - r) // stride + 1


def _im2col(x: jnp.ndarray, r: int, s: int, stride: int,
            pad: Tuple[int, int, int, int]) -> jnp.ndarray:
    """x: (N,H,W,C) -> (N, U, V, R*S*C) patches, (r, s, c)-ordered."""
    n, h, w, c = x.shape
    plo_h, phi_h, plo_w, phi_w = pad
    xp = jnp.pad(x, ((0, 0), (plo_h, phi_h), (plo_w, phi_w), (0, 0)))
    hp, wp = h + plo_h + phi_h, w + plo_w + phi_w
    u = (hp - r) // stride + 1
    v = (wp - s) // stride + 1
    cols = []
    for dr in range(r):
        for ds in range(s):
            cols.append(
                jax.lax.slice(
                    xp, (0, dr, ds, 0),
                    (n, dr + (u - 1) * stride + 1, ds + (v - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    patches = jnp.stack(cols, axis=3)          # (N,U,V,R*S,C)
    return patches.reshape(n, u, v, r * s * c)


def _dilate_hw(x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Insert stride-1 zeros between spatial elements (for grad-input)."""
    if stride == 1:
        return x
    n, h, w, c = x.shape
    out = jnp.zeros((n, (h - 1) * stride + 1, (w - 1) * stride + 1, c), x.dtype)
    return out.at[:, ::stride, ::stride, :].set(x)


# ---------------------------------------------------------------------------
# Bitmap derivation (no tensor-sized scans past this line)
# ---------------------------------------------------------------------------

def _patch_bitmap(st: SparseTensor, spatial: Tuple[int, int, int, int],
                  r: int, s: int, stride: int,
                  pad: Tuple[int, int, int, int]) -> SparseTensor:
    """im2col in bitmap space: (N·H·W, C/gc) fine bitmap -> fine bitmap of
    the patch matrix (N·U·V, R·S·C/gc), exactly matching a fresh scan of
    ``_im2col(data)``.  Pure gather on the bitmap — the activation is not
    touched."""
    n, h, w, c = spatial
    gc = st.gran[1]
    fb4 = st.bitmap.reshape(n, h, w, c // gc)
    pb = _im2col(fb4, r, s, stride, pad)       # (N, U, V, R*S*C/gc)
    u, v = pb.shape[1], pb.shape[2]
    return SparseTensor(None, pb.reshape(n * u * v, -1), (1, gc))


def _encode_conv_act(x_pre: jnp.ndarray, policy: SparsityPolicy,
                     gc: int) -> Tuple[jnp.ndarray, SparseTensor]:
    """(relu(x_pre), SparseTensor over the (N·H·W, C) view) — ONE fused
    encode (pallas) or one counted scan (xla_ref) per activation per step."""
    n, h, w, c = x_pre.shape
    x2d = x_pre.reshape(n * h * w, c)
    if policy.kernel_impl == "pallas":
        y2d, fb = kops.relu_encode(x2d, block=(1, gc),
                                   interpret=policy.interpret)
        x = y2d.reshape(n, h, w, c)
    else:
        x = jnp.maximum(x_pre, jnp.zeros((), x_pre.dtype))
        fb = scan_bitmap(x.reshape(n * h * w, c), (1, gc), kind="act")
    return x, SparseTensor(x_pre, fb, (1, gc))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def relu_conv(x_pre: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str,
              policy: SparsityPolicy):
    """y = conv2d(relu(x_pre), w). x_pre: (N,H,W,C); w: (R,S,C,M)."""
    y, _ = _relu_conv_fwd(x_pre, w, stride, padding, policy)
    return y


def _relu_conv_fwd(x_pre, w, stride, padding, policy: SparsityPolicy):
    n, h, wd, c = x_pre.shape
    r, s, _, m = w.shape
    bm, bk, bn = policy.block
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    pad4 = (plh[0], plh[1], plw[0], plw[1])

    if _needs_act_bitmap(policy):
        gc = conv_channel_granularity(c, policy.block)
        x, st = _encode_conv_act(x_pre, policy, gc)
    else:
        x = jnp.maximum(x_pre, jnp.zeros((), x_pre.dtype))
        st = SparseTensor(x_pre, None, None)

    patches = _im2col(x, r, s, stride, pad4)
    u, v = patches.shape[1], patches.shape[2]
    pm = patches.reshape(n * u * v, r * s * c)
    wm = w.reshape(r * s * c, m)
    a_mask = None
    if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas":
        a_mask = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4) \
            .mask_for((bm, bk))
    y = _mm(pm, wm, None, a_mask, None, policy, x_pre.dtype)
    return y.reshape(n, u, v, m), (st, w)


def _grad_sparse_tensor(dy32: jnp.ndarray, policy: SparsityPolicy,
                        m: int) -> SparseTensor:
    """Fine bitmap of the incoming gradient — the step's single dy scan."""
    if not _needs_grad_bitmap(policy):
        return SparseTensor(dy32, None, None)
    n, u, v, _ = dy32.shape
    gc = conv_channel_granularity(m, policy.block)
    fb = scan_bitmap(dy32.reshape(n * u * v, m), (1, gc), kind="grad")
    return SparseTensor(dy32, fb, (1, gc))


def _relu_conv_bwd(stride, padding, policy: SparsityPolicy, res, dy):
    st, w = res
    x_pre = st.data
    n, h, wd, c = x_pre.shape
    r, s, _, m = w.shape
    u, v = dy.shape[1], dy.shape[2]
    mask = (x_pre > 0)
    x = jnp.where(mask, x_pre, jnp.zeros((), x_pre.dtype))
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)
    st_dy = _grad_sparse_tensor(dy32, policy, m)

    # ---- dx_pre: full-correlation of dilated dy with flipped w, fused with
    # the σ' Hadamard → OUTPUT sparsity on the (N·H·W, C) GEMM. ----
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    dyd = _dilate_hw(dy32, stride)
    hd, wdd = dyd.shape[1], dyd.shape[2]
    # output spatial size must equal (h, wd):  pad_lo = r-1-fwd_pad_lo
    pg_h_lo = r - 1 - plh[0]
    pg_h_hi = h - (hd + pg_h_lo - r + 1) + 0  # solve for hi
    pg_w_lo = s - 1 - plw[0]
    pg_w_hi = wd - (wdd + pg_w_lo - s + 1)
    gpad4 = (pg_h_lo, pg_h_hi, pg_w_lo, pg_w_hi)
    gpatches = _im2col(dyd, r, s, 1, gpad4)
    gm = gpatches.reshape(n * h * wd, r * s * m)
    # w flipped spatially, (r, s, m, c) ordering to match patch layout
    wt = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2).reshape(r * s * m, c)
    mask2d = mask.reshape(n * h * wd, c).astype(jnp.float32)
    # out_mask: the forward ReLU bitmap, re-tiled (footprint(σ') ==
    # footprint(relu) — paper §3.2).  Zero recompute.
    out_mask = st.mask_for((bm, bn)) if policy.use_output_sparsity else None
    g_mask = None
    if st_dy.bitmap is not None:
        # The gradient-patch mask is the dy bitmap dilated and im2col'd in
        # bitmap space — mirrors exactly what the data underwent.
        gcg = st_dy.gran[1]
        gfb4 = st_dy.bitmap.reshape(n, u, v, m // gcg)
        gpb = _im2col(_dilate_hw(gfb4, stride), r, s, 1, gpad4)
        g_mask = coarsen_bitmap(gpb.reshape(n * h * wd, -1), (1, gcg),
                                (bm, bk))
    dx = _mm(gm, wt.astype(jnp.float32), out_mask, g_mask, None, policy,
             x_pre.dtype, epilogue=mask2d)
    dx_pre = dx.reshape(n, h, wd, c)

    # ---- dW = patches(x)ᵀ @ dy — WG stage, input sparsity both sides ----
    pad4 = (plh[0], plh[1], plw[0], plw[1])
    patches = _im2col(x, r, s, stride, pad4)
    pm = patches.reshape(n * u * v, r * s * c).astype(jnp.float32)
    dym = dy32.reshape(n * u * v, m)
    pt = pm.T
    pt_mask = None
    if _needs_grad_bitmap(policy) and st.bitmap is not None:
        # Xᵀ patch mask: forward bitmap -> patch bitmap -> block transpose.
        pt_mask = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4) \
            .t_mask_for((bm, bk))
    dym_mask = st_dy.mask_for((bk, bn))
    dw = _mm(pt, dym, None, pt_mask, dym_mask, policy, jnp.float32)
    return dx_pre, dw.reshape(r, s, c, m).astype(w.dtype)


relu_conv.defvjp(_relu_conv_fwd, _relu_conv_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def conv(x: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str,
         policy: SparsityPolicy):
    """Plain conv2d (no fused ReLU): FP/BP input sparsity only.

    Used at MaxPool→CONV and input-layer boundaries where the paper notes
    output sparsity is not applicable (Fig. 11 discussion).  The input's
    nonzero bitmap is still computed only once (one counted scan — x may be
    signed, so the fused ReLU encode does not apply) and threaded to the
    forward operand mask and the WG transposed mask.
    """
    y, _ = _conv_fwd(x, w, stride, padding, policy)
    return y


def _conv_fwd(x, w, stride, padding, policy):
    n, h, wd, c = x.shape
    r, s, _, m = w.shape
    bm, bk, bn = policy.block
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    pad4 = (plh[0], plh[1], plw[0], plw[1])
    st = SparseTensor(x, None, None)
    if policy.kernel_impl == "pallas" and (
            policy.use_input_sparsity_fp or policy.use_input_sparsity_bp):
        gc = conv_channel_granularity(c, policy.block)
        st = SparseTensor(
            x, scan_bitmap(x.reshape(n * h * wd, c), (1, gc), kind="act"),
            (1, gc))
    patches = _im2col(x, r, s, stride, pad4)
    u, v = patches.shape[1], patches.shape[2]
    pm = patches.reshape(n * u * v, r * s * c)
    a_mask = None
    if policy.use_input_sparsity_fp and policy.kernel_impl == "pallas" \
            and st.bitmap is not None:
        a_mask = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4) \
            .mask_for((bm, bk))
    y = _mm(pm, w.reshape(r * s * c, m), None, a_mask, None, policy, x.dtype)
    return y.reshape(n, u, v, m), (st, w)


def _conv_bwd(stride, padding, policy, res, dy):
    st, w = res
    x = st.data
    # Identical to relu_conv's backward with an all-ones mask and no output
    # sparsity.
    n, h, wd, c = x.shape
    r, s, _, m = w.shape
    u, v = dy.shape[1], dy.shape[2]
    bm, bk, bn = policy.block
    dy32 = dy.astype(jnp.float32)
    st_dy = _grad_sparse_tensor(dy32, policy, m)
    plh = _pad_amounts(h, r, stride, padding)
    plw = _pad_amounts(wd, s, stride, padding)
    dyd = _dilate_hw(dy32, stride)
    hd, wdd = dyd.shape[1], dyd.shape[2]
    pg_h_lo = r - 1 - plh[0]
    pg_h_hi = h - (hd + pg_h_lo - r + 1)
    pg_w_lo = s - 1 - plw[0]
    pg_w_hi = wd - (wdd + pg_w_lo - s + 1)
    gpad4 = (pg_h_lo, pg_h_hi, pg_w_lo, pg_w_hi)
    gpatches = _im2col(dyd, r, s, 1, gpad4)
    gm = gpatches.reshape(n * h * wd, r * s * m)
    wt = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2).reshape(r * s * m, c)
    g_mask = None
    if st_dy.bitmap is not None:
        gcg = st_dy.gran[1]
        gfb4 = st_dy.bitmap.reshape(n, u, v, m // gcg)
        gpb = _im2col(_dilate_hw(gfb4, stride), r, s, 1, gpad4)
        g_mask = coarsen_bitmap(gpb.reshape(n * h * wd, -1), (1, gcg),
                                (bm, bk))
    dx = _mm(gm, wt.astype(jnp.float32), None, g_mask, None, policy, x.dtype)
    dx = dx.reshape(n, h, wd, c)

    pad4 = (plh[0], plh[1], plw[0], plw[1])
    patches = _im2col(x, r, s, stride, pad4)
    pm = patches.reshape(n * u * v, r * s * c).astype(jnp.float32)
    dym = dy32.reshape(n * u * v, m)
    pt = pm.T
    pt_mask = None
    if st.bitmap is not None and _needs_grad_bitmap(policy):
        pt_mask = _patch_bitmap(st, (n, h, wd, c), r, s, stride, pad4) \
            .t_mask_for((bm, bk))
    dym_mask = st_dy.mask_for((bk, bn))
    dw = _mm(pt, dym, None, pt_mask, dym_mask, policy, jnp.float32)
    return dx, dw.reshape(r, s, c, m).astype(w.dtype)


conv.defvjp(_conv_fwd, _conv_bwd)
