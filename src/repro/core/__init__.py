"""The paper's contribution: activation-based gradient OUTPUT sparsity
(plus input sparsity) for backpropagation, as composable JAX ops, the WDU
load-balancing policy, and the trace-driven accelerator cost model."""
from . import costmodel, policy, sparsity, workredist  # noqa: F401
from .policy import DC, IN, IN_OUT, IN_OUT_WR, OUT, SCENARIOS, SparsityPolicy  # noqa: F401
from .sparse_conv import (  # noqa: F401
    conv, depthwise_conv, depthwise_relu_conv, relu_conv,
)
from .sparse_linear import act_matmul, matmul, relu_matmul  # noqa: F401
from .sparse_tensor import SparseTensor, coarsen_bitmap  # noqa: F401
