"""Execution policies — the paper's four evaluation scenarios as config.

DC          dense compute (sparsity-agnostic baseline)
IN          input sparsity only (prior work: CNVLUTIN/SparTANN class)
IN_OUT      input + output sparsity (the paper's contribution)
IN_OUT_WR   + work redistribution (paper's full system; on TPU this picks
            the compacted work-queue kernel schedule)

``kernel_impl`` selects how the skipping executes:
  * "pallas"  — the Pallas kernels (interpret-mode on CPU, native on TPU);
  * "xla_ref" — numerically identical pure-jnp path (dense compute + mask)
                so CPU-bound examples/training run at XLA speed while the
                cost model still accounts the skipped work.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SparsityPolicy:
    use_input_sparsity_fp: bool = False   # FP: skip zero activation operands
    use_input_sparsity_bp: bool = False   # BP: skip zero gradient operands
    use_output_sparsity: bool = False     # BP: skip outputs the ReLU mask kills
    work_redistribution: bool = False     # compacted work-queue schedule
    queue_builder: Literal["prefix_sum", "argsort"] = "prefix_sum"
                                          # how the compact queue is built:
                                          # on-device Pallas prefix-sum
                                          # compaction (O(T), default) or the
                                          # retained argsort reference
                                          # (O(T log T), host-side sort)
    block: Tuple[int, int, int] = (128, 128, 128)
    kernel_impl: Literal["pallas", "xla_ref"] = "xla_ref"
    interpret: Optional[bool] = None      # None → auto (CPU backend ⇒ True)
    fuse_epilogue: bool = True            # BP: σ'-Hadamard inside the kernel
                                          # (False = separate VPU pass, for
                                          # ablating the fused writeback)

    @property
    def any_sparsity(self) -> bool:
        return (
            self.use_input_sparsity_fp
            or self.use_input_sparsity_bp
            or self.use_output_sparsity
        )

    def with_(self, **kw) -> "SparsityPolicy":
        return dataclasses.replace(self, **kw)


DC = SparsityPolicy()
IN = SparsityPolicy(use_input_sparsity_fp=True, use_input_sparsity_bp=True)
OUT = SparsityPolicy(use_output_sparsity=True)
IN_OUT = SparsityPolicy(
    use_input_sparsity_fp=True,
    use_input_sparsity_bp=True,
    use_output_sparsity=True,
)
IN_OUT_WR = IN_OUT.with_(work_redistribution=True)

SCENARIOS = {"DC": DC, "IN": IN, "OUT": OUT, "IN_OUT": IN_OUT, "IN_OUT_WR": IN_OUT_WR}
