"""Execution policies — the paper's four evaluation scenarios as config.

DC          dense compute (sparsity-agnostic baseline)
IN          input sparsity only (prior work: CNVLUTIN/SparTANN class)
IN_OUT      input + output sparsity (the paper's contribution)
IN_OUT_WR   + work redistribution (paper's full system; on TPU this picks
            the compacted work-queue kernel schedule)

``kernel_impl`` selects how the skipping executes:
  * "pallas"  — the Pallas kernels (interpret-mode on CPU, native on TPU);
  * "xla_ref" — numerically identical pure-jnp path (dense compute + mask)
                so CPU-bound examples/training run at XLA speed while the
                cost model still accounts the skipped work.

``SparsityPolicy.gemm_spec(...)`` is the ONE policy→kernel resolution
point: it maps a policy (plus per-GEMM dims/granularity) onto the frozen
``kernels.ops.GemmSpec`` that ``sparse_gemm`` dispatches on, including the
degenerate grouped tiles of ``grouped_gemm_block``.  No layer above
kernels/ threads schedule/queue/epilogue kwargs by hand anymore.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

import jax.numpy as jnp

from repro.kernels import autotune as _autotune
from repro.kernels.ops import GemmSpec
from repro.kernels.shapes import ceil_to


@dataclasses.dataclass(frozen=True)
class SparsityPolicy:
    use_input_sparsity_fp: bool = False   # FP: skip zero activation operands
    use_input_sparsity_bp: bool = False   # BP: skip zero gradient operands
    use_output_sparsity: bool = False     # BP: skip outputs the ReLU mask kills
    work_redistribution: bool = False     # compacted work-queue schedule
    queue_builder: Literal["prefix_sum", "argsort"] = "prefix_sum"
                                          # how the compact queue is built:
                                          # on-device Pallas prefix-sum
                                          # compaction (O(T), default) or the
                                          # retained argsort reference
                                          # (O(T log T), host-side sort)
    block: Tuple[int, int, int] = (128, 128, 128)
    grouped_block: Optional[Tuple[int, int, int]] = None
                                          # nominal tile for the per-group
                                          # GEMMs of grouped/depthwise convs
                                          # (None → derive from `block`; each
                                          # edge then shrinks to the
                                          # granularity-rounded per-group dim
                                          # via grouped_gemm_block, so tiny
                                          # K = R·S·C/G axes get degenerate
                                          # blocks that still mask instead of
                                          # one huge block that masks nothing)
    grouped_sparsity_min_k: int = 1       # per-group contraction length below
                                          # which operand masks are dropped
                                          # for grouped GEMMs (threshold knob:
                                          # a K axis shorter than this can't
                                          # amortize its bitmap; 1 = always
                                          # mask — depthwise K = R·S ≥ 9
                                          # still captures spatial zeros)
    kernel_impl: Literal["pallas", "xla_ref"] = "xla_ref"
    interpret: Optional[bool] = None      # None → auto (CPU backend ⇒ True)
    fuse_epilogue: bool = True            # BP: σ'-Hadamard inside the kernel
                                          # (False = separate VPU pass, for
                                          # ablating the fused writeback)
    scan_signed_inputs: bool = False      # FP: opt-in standalone bitmap_scan
                                          # of signed RAW model inputs (no
                                          # ReLU to fuse into).  Off by
                                          # default: the first layer's input
                                          # is near-dense, so the scan rarely
                                          # pays for itself — and with dy
                                          # bitmaps emitted by the producing
                                          # GEMM's epilogue, the training hot
                                          # path then launches ZERO
                                          # scan_pallas:* passes
    autotune: bool = False                # measured-stats schedule/tile
                                          # selection: gemm_spec consults the
                                          # kernels/autotune cache (keyed on
                                          # spec-minus-schedule + padded
                                          # shape, fed by live-tile stats of
                                          # recent dispatches) instead of
                                          # taking the static resolution —
                                          # the static choice stays the
                                          # fallback until enough samples
                                          # accumulate (docs/benchmarking.md)

    @property
    def any_sparsity(self) -> bool:
        return (
            self.use_input_sparsity_fp
            or self.use_input_sparsity_bp
            or self.use_output_sparsity
        )

    def with_(self, **kw) -> "SparsityPolicy":
        return dataclasses.replace(self, **kw)

    def gemm_spec(
        self,
        *,
        groups: int = 1,
        dims: Optional[Tuple[int, int, int]] = None,
        grans: Tuple[int, int, int] = (1, 1, 1),
        out_dtype=jnp.float32,
        fused_epilogue: bool = False,
        max_active_blocks: Optional[int] = None,
    ) -> GemmSpec:
        """Policy → ``kernels.ops.GemmSpec`` resolution, in ONE place.

        ``dims``/``grans`` are the per-group (M, K, N) GEMM dims and the
        bitmap granularity each axis requires: when given, the tile is the
        degenerate ``grouped_gemm_block`` shape (each edge shrinks to the
        granularity-rounded dim — works at any G, including G=1); when
        None, the policy's nominal ``block``.  Schedule resolution:
        ``kernel_impl != "pallas"`` ⇒ "dense" (masked dense compute),
        ``work_redistribution`` ⇒ "compact", else "predicated".
        ``fused_epilogue`` declares a σ′-Hadamard fused into the writeback
        (callers pass the multiplier itself to ``sparse_gemm``).

        With ``autotune=True`` the static resolution above becomes the
        DEFAULT, and the ``kernels/autotune`` cache may retarget schedule
        (and, when ``dims`` are given, tile edges — granularity-safely)
        from measured live-tile stats of recent dispatches.  The resolved
        spec keeps ``origin="policy"``: autotuning is still this one
        sanctioned resolution point, just measurement-driven.

        Quarantine (docs/resilience.md) applies on EVERY resolution path,
        autotuned or not: a key the guard layer demoted down the
        degradation ladder (compact → predicated → dense — persistent
        queue overflow, bitmap-consistency trips) is clamped to its
        allowed schedule here, so a misbehaving spec cannot re-enter the
        compact path by being resolved statically.
        """
        block = grouped_gemm_block(self, dims, grans) \
            if dims is not None else self.block
        if self.kernel_impl != "pallas":
            schedule = "dense"
        elif self.work_redistribution:
            schedule = "compact"
        else:
            schedule = "predicated"
        spec = GemmSpec(
            block=block,
            groups=groups,
            schedule=schedule,
            epilogue="sigma_prime" if fused_epilogue else "none",
            queue_builder=self.queue_builder,
            max_active_blocks=max_active_blocks,
            out_dtype=out_dtype,
            interpret=self.interpret,
            origin="policy",
        )
        if self.autotune:
            # resolve() applies the quarantine clamp inside the cache.
            spec = _autotune.resolve(spec, dims=dims, grans=grans)
        else:
            spec = _autotune.apply_quarantine(spec, dims=dims)
        return spec


def grouped_gemm_block(
    policy: SparsityPolicy,
    dims: Tuple[int, int, int],
    grans: Tuple[int, int, int] = (1, 1, 1),
) -> Tuple[int, int, int]:
    """Degenerate tile selection for one per-group GEMM of a grouped conv.

    ``dims`` are the per-group (M, K, N) of the GEMM; ``grans`` the bitmap
    granularity each axis's masks require (edges must stay multiples of it
    so derived masks coarsen exactly).  Each nominal edge shrinks to the
    granularity-rounded dimension: a depthwise K of R·S = 9 gets a 9-ish
    block (one K step, per-patch-row masking still live) instead of a 128
    block that pads 14× and can never skip — the "degenerate block shapes
    rather than silently masking nothing" rule.
    """
    nominal = policy.grouped_block or policy.block
    out = []
    for b, d, g in zip(nominal, dims, grans):
        e = min(b, ceil_to(d, g))
        e = max(g, ceil_to(e, g))    # keep a multiple of the granularity
        out.append(e)
    return tuple(out)


DC = SparsityPolicy()
IN = SparsityPolicy(use_input_sparsity_fp=True, use_input_sparsity_bp=True)
OUT = SparsityPolicy(use_output_sparsity=True)
IN_OUT = SparsityPolicy(
    use_input_sparsity_fp=True,
    use_input_sparsity_bp=True,
    use_output_sparsity=True,
)
IN_OUT_WR = IN_OUT.with_(work_redistribution=True)

SCENARIOS = {"DC": DC, "IN": IN, "OUT": OUT, "IN_OUT": IN_OUT, "IN_OUT_WR": IN_OUT_WR}
