"""Work ReDistribution Unit (WDU) — faithful model of paper §4.6.

Each PE-tile owns a slice (U/Tx × V/Ty) of the output map; spatial sparsity
variation makes some tiles finish early.  The WDU tracks per-tile progress
as a state tuple <iter, x, y>, detects idle ("source") tiles, picks the
lexicographically-most-behind ("target") tile, and if the target's
remaining work exceeds a threshold (paper: 30%), splits the remaining work
in half and reassigns the lower half to the idle tile.

We reproduce this as a discrete-event simulation over per-tile work counts
(active MACs measured from real masks).  It drives Fig. 17 (min/avg/max
tile latency; ~70% → ~83% utilization) and the WR bars of Figs. 11–15.

On the TPU port the same policy is realized *statically* by the compacted
work-queue kernel (kernels/masked_matmul.compact_masked_matmul_kernel);
this module is the dynamic-hardware reference the static schedule is
compared against.  ``static_queue_order`` below is the executable contract
for the ORDER of that static queue — both queue builders in
``kernels.ops.build_queue`` (the Pallas prefix-sum compaction and the
argsort reference) are property-tested against it; the full queue
lifecycle (bitmap → prefix sum → queue → scatter-back, overflow
semantics) is documented in docs/bitmap_lifecycle.md.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class WDUResult:
    makespan: float          # cycles until the last tile finishes
    busy_min: float
    busy_avg: float
    busy_max: float
    utilization: float       # Σ busy / (n_tiles × makespan)
    n_redistributions: int


def simulate(
    work: np.ndarray,
    *,
    redistribute: bool = True,
    threshold: float = 0.30,
    split: float = 0.5,
    redistribution_overhead: float = 0.02,
) -> WDUResult:
    """Simulate one layer-phase execution over per-tile work counts.

    work[i] = active MACs assigned to tile i (already scaled by the tile's
    PE throughput, so 1 work unit = 1 cycle).  ``threshold`` gates a
    transfer on remaining/original fraction of the *target* tile, per the
    paper's empirical 30% lower bound.  ``redistribution_overhead`` charges
    the input-sharing + result-merge cost as a fraction of moved work.
    """
    remaining = work.astype(np.float64).copy()
    original = np.maximum(work.astype(np.float64), 1e-9)
    busy = np.zeros_like(remaining)
    t = 0.0
    n_redist = 0
    active = remaining > 0
    while active.any():
        dt = remaining[active].min()
        t += dt
        busy[active] += dt
        remaining[active] -= dt
        remaining[np.abs(remaining) < 1e-9] = 0.0
        active = remaining > 0
        if not redistribute:
            continue
        idle = np.flatnonzero(~active)
        for src in idle:
            if not active.any():
                break
            tgt = int(np.argmax(remaining))
            if remaining[tgt] <= 0:
                break
            if remaining[tgt] / original[tgt] < threshold:
                continue  # not worth the transfer overhead
            moved = remaining[tgt] * split
            remaining[tgt] -= moved
            remaining[src] += moved * (1.0 + redistribution_overhead)
            n_redist += 1
            active = remaining > 0
    util = float(busy.sum() / (len(work) * t)) if t > 0 else 1.0
    return WDUResult(
        makespan=float(t),
        busy_min=float(busy.min()),
        busy_avg=float(busy.mean()),
        busy_max=float(busy.max()),
        utilization=util,
        n_redistributions=n_redist,
    )


def wdu_dispatch_order(bitmap: np.ndarray) -> list:
    """The WDU's tile-dispatch rule, executed literally (paper §4.6): among
    the remaining active tiles, repeatedly pick the one with the
    lexicographically smallest state tuple — i.e. smallest (i, j).  O(T²)
    by construction; exists only to pin ``static_queue_order`` (and through
    it both kernel queue builders) to the paper's rule, not to be fast."""
    remaining = {(int(i), int(j))
                 for i, j in zip(*np.nonzero(np.asarray(bitmap) != 0))}
    order = []
    while remaining:
        nxt = min(remaining)               # lexicographic on the (i, j) tuple
        order.append(nxt)
        remaining.remove(nxt)
    return order


def static_queue_order(
    bitmap: np.ndarray,
    capacity: int = 0,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """REFERENCE order of the static work queue: ``(ii, jj, n_live)``.

    Row-major coordinates of the set bits of a (Mb, Nb) tile bitmap — which
    is exactly the WDU dispatch order (``wdu_dispatch_order``), since
    row-major (i, j) IS ascending lexicographic on the state tuple.  Both
    the Pallas prefix-sum builder and the argsort reference in
    ``kernels.ops.build_queue`` must emit this order bit-for-bit
    (tests/test_queue_builder.py).

    ``capacity`` > 0 pads/truncates ``ii``/``jj`` to that many slots (dead
    slots are zero — valid coords for the consumer's gathers); ``n_live``
    is always the true set-bit count, so callers can detect overflow.
    """
    bm = np.asarray(bitmap) != 0
    ri, rj = np.nonzero(bm)                # C order == row-major == WDU order
    n_live = int(ri.size)
    cap = capacity if capacity > 0 else bm.size
    ii = np.zeros(cap, np.int32)
    jj = np.zeros(cap, np.int32)
    k = min(n_live, cap)
    ii[:k] = ri[:k]
    jj[:k] = rj[:k]
    return ii, jj, n_live


def tile_work_from_mask(
    active_outputs: np.ndarray,  # (U, V) work per output location
    tx: int,
    ty: int,
    macs_per_output: float,
) -> np.ndarray:
    """Partition a (U, V) work map into the paper's Tx×Ty PE tiles and
    return per-tile MAC counts (work-conserving fractional binning, so a
    map of any resolution — including < Tx — bins without zero-padding
    artifacts).  Halo effects are second-order and ignored, as in the
    paper's own mapping discussion (§4.2)."""
    import math
    u, v = active_outputs.shape
    su = math.lcm(u, tx) // u
    sv = math.lcm(v, ty) // v
    a = np.kron(active_outputs, np.ones((su, sv))) / (su * sv)
    u2, v2 = a.shape
    tiles = a.reshape(tx, u2 // tx, ty, v2 // ty).sum(axis=(1, 3))
    return (tiles * macs_per_output).reshape(-1)
