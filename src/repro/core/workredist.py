"""Work ReDistribution Unit (WDU) — faithful model of paper §4.6.

Each PE-tile owns a slice (U/Tx × V/Ty) of the output map; spatial sparsity
variation makes some tiles finish early.  The WDU tracks per-tile progress
as a state tuple <iter, x, y>, detects idle ("source") tiles, picks the
lexicographically-most-behind ("target") tile, and if the target's
remaining work exceeds a threshold (paper: 30%), splits the remaining work
in half and reassigns the lower half to the idle tile.

We reproduce this as a discrete-event simulation over per-tile work counts
(active MACs measured from real masks).  It drives Fig. 17 (min/avg/max
tile latency; ~70% → ~83% utilization) and the WR bars of Figs. 11–15.

On the TPU port the same policy is realized *statically* by the compacted
work-queue kernel (kernels/masked_matmul.compact_masked_matmul_kernel);
this module is the dynamic-hardware reference the static schedule is
compared against.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class WDUResult:
    makespan: float          # cycles until the last tile finishes
    busy_min: float
    busy_avg: float
    busy_max: float
    utilization: float       # Σ busy / (n_tiles × makespan)
    n_redistributions: int


def simulate(
    work: np.ndarray,
    *,
    redistribute: bool = True,
    threshold: float = 0.30,
    split: float = 0.5,
    redistribution_overhead: float = 0.02,
) -> WDUResult:
    """Simulate one layer-phase execution over per-tile work counts.

    work[i] = active MACs assigned to tile i (already scaled by the tile's
    PE throughput, so 1 work unit = 1 cycle).  ``threshold`` gates a
    transfer on remaining/original fraction of the *target* tile, per the
    paper's empirical 30% lower bound.  ``redistribution_overhead`` charges
    the input-sharing + result-merge cost as a fraction of moved work.
    """
    remaining = work.astype(np.float64).copy()
    original = np.maximum(work.astype(np.float64), 1e-9)
    busy = np.zeros_like(remaining)
    t = 0.0
    n_redist = 0
    active = remaining > 0
    while active.any():
        dt = remaining[active].min()
        t += dt
        busy[active] += dt
        remaining[active] -= dt
        remaining[np.abs(remaining) < 1e-9] = 0.0
        active = remaining > 0
        if not redistribute:
            continue
        idle = np.flatnonzero(~active)
        for src in idle:
            if not active.any():
                break
            tgt = int(np.argmax(remaining))
            if remaining[tgt] <= 0:
                break
            if remaining[tgt] / original[tgt] < threshold:
                continue  # not worth the transfer overhead
            moved = remaining[tgt] * split
            remaining[tgt] -= moved
            remaining[src] += moved * (1.0 + redistribution_overhead)
            n_redist += 1
            active = remaining > 0
    util = float(busy.sum() / (len(work) * t)) if t > 0 else 1.0
    return WDUResult(
        makespan=float(t),
        busy_min=float(busy.min()),
        busy_avg=float(busy.mean()),
        busy_max=float(busy.max()),
        utilization=util,
        n_redistributions=n_redist,
    )


def tile_work_from_mask(
    active_outputs: np.ndarray,  # (U, V) work per output location
    tx: int,
    ty: int,
    macs_per_output: float,
) -> np.ndarray:
    """Partition a (U, V) work map into the paper's Tx×Ty PE tiles and
    return per-tile MAC counts (work-conserving fractional binning, so a
    map of any resolution — including < Tx — bins without zero-padding
    artifacts).  Halo effects are second-order and ignored, as in the
    paper's own mapping discussion (§4.2)."""
    import math
    u, v = active_outputs.shape
    su = math.lcm(u, tx) // u
    sv = math.lcm(v, ty) // v
    a = np.kron(active_outputs, np.ones((su, sv))) / (su * sv)
    u2, v2 = a.shape
    tiles = a.reshape(tx, u2 // tx, ty, v2 // ty).sum(axis=(1, 3))
    return (tiles * macs_per_output).reshape(-1)
