"""Sparsity footprints, bitmaps and statistics (paper §3).

The paper's two structural views of a (C, H, W) feature map:

  * Through-Channel (TC) sparsity — per spatial location, zeros along C.
    Drives INPUT sparsity (the offset-lane indexing of §4.1 / Fig. 8a).
  * Within-Channel (WC) sparsity — per channel, zeros across H×W.
    Drives OUTPUT sparsity (the output bitmap of Fig. 9).

On TPU both become *block bitmaps* over a 2-D GEMM view of the tensor
(tokens/pixels × features).  This module provides the bitmap builders, the
element↔block "capture rate" diagnostics quoted in DESIGN.md, and the
footprint-identity check (forward activation footprint == backward gradient
footprint across a ReLU), which is the paper's central theorem and is
property-tested in tests/.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref

# Re-exports so core is self-contained for callers.
block_any_nonzero = kref.block_any_nonzero
expand_block_mask = kref.expand_block_mask


def relu_mask(z: jnp.ndarray) -> jnp.ndarray:
    """σ'(z) for ReLU — the footprint captured in the forward pass.

    Note ``z > 0`` (not >=): gradients at exactly 0 are zeroed, matching the
    convention σ'(0)=0 used by the paper's eq. for σ' and by jax's
    ``jnp.maximum`` vjp for the x==0 subgradient choice at x<=0.
    """
    return (z > 0).astype(z.dtype)


def element_sparsity(x: jnp.ndarray) -> jnp.ndarray:
    """Fraction of exactly-zero elements."""
    return jnp.mean((x == 0).astype(jnp.float32))


def block_sparsity(x2d: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """Fraction of fully-zero (bm, bn) blocks in a 2-D view."""
    bitmap = block_any_nonzero(x2d, bm, bn)
    return 1.0 - jnp.mean(bitmap.astype(jnp.float32))


def capture_rate(x2d: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """Fraction of zero *elements* that live inside fully-zero *blocks*.

    = (how much of the paper's element-granular skipping opportunity the
    TPU block-granular schedule captures).  1.0 when zeros are perfectly
    clustered; → 0 as zeros become i.i.d. at low sparsity.
    """
    zeros = (x2d == 0).astype(jnp.float32)
    total_zero = zeros.sum()
    bitmap = block_any_nonzero(x2d, bm, bn)
    dead = expand_block_mask(1 - bitmap, bm, bn).astype(jnp.float32)
    captured = (zeros * dead).sum()
    return jnp.where(total_zero > 0, captured / total_zero, 1.0)


def tc_sparsity(x_chw: jnp.ndarray) -> jnp.ndarray:
    """Through-channel sparsity per (H, W) location: mean fraction of zero
    channels (paper §4.2, Fig. 7a)."""
    return jnp.mean((x_chw == 0).astype(jnp.float32), axis=0)


def wc_sparsity(x_chw: jnp.ndarray) -> jnp.ndarray:
    """Within-channel sparsity per channel: fraction of zero pixels
    (paper §4.2, Fig. 7c)."""
    c = x_chw.shape[0]
    return jnp.mean((x_chw == 0).reshape(c, -1).astype(jnp.float32), axis=1)


@dataclasses.dataclass(frozen=True)
class SparsityStats:
    element: float
    block: float
    capture: float

    @staticmethod
    def of(x2d: jnp.ndarray, bm: int, bn: int) -> "SparsityStats":
        return SparsityStats(
            element=float(element_sparsity(x2d)),
            block=float(block_sparsity(x2d, bm, bn)),
            capture=float(capture_rate(x2d, bm, bn)),
        )


def footprints_identical(fwd_act: jnp.ndarray, bwd_grad_pre: jnp.ndarray) -> bool:
    """Paper §3.2: zeros of relu(z) ⊇ zeros of δ_pre = δ_post ⊙ σ'(z).

    Every location where the forward activation is zero must have zero
    pre-activation gradient (δ can have *extra* zeros where δ_post happens
    to be 0 — the containment is one-directional, which is exactly what
    makes the forward footprint a safe skip-list).
    """
    fwd_zero = fwd_act == 0
    grad_nonzero = bwd_grad_pre != 0
    return bool(jnp.logical_not(jnp.any(fwd_zero & grad_nonzero)))
