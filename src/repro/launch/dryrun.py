import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
  * jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)
    must compile for the 16×16 single-pod mesh AND the 2×16×16 multi-pod
    mesh, for every cell;
  * compiled.memory_analysis() proves per-device fit (16 GB v5e budget);
  * compiled.cost_analysis() + collective parsing feed §Roofline.

Results stream to JSONL under benchmarks/results/.

NOTE the XLA_FLAGS assignment above MUST precede any jax import (device
count locks at first backend init) — which is why this module sets it
before its own docstring-adjacent imports and why nothing else in the
repo sets it globally.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_SHAPES, ARCHS, SMOKE_ARCHS, runs_cell
from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import decode_input_specs, train_input_specs
from repro.launch import flops as aflops
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.transformer import lm_init
from repro.optim.optimizer import OptConfig, adamw_init
from repro.sharding import partition, sharding_rules

HBM_PER_CHIP = 16 * 1024 ** 3        # v5e
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def _eval_shapes(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def pick_microbatches(cfg, shape: ShapeConfig, mesh) -> int:
    """Grad-accum splits so per-shard live tokens stay ~8k (activation
    footprint control for the ≥100B configs)."""
    if shape.kind != "train":
        return 1
    dp = partition.axis_size(mesh, partition.dp_axis_names(mesh))
    local_seqs = max(1, shape.global_batch // dp)
    tokens_per_seq = shape.seq_len
    target = 8192
    mb = max(1, (local_seqs * tokens_per_seq) // target)
    while local_seqs % mb != 0:
        mb -= 1
    return max(1, mb)


def lower_cell(arch: str, cfg, shape: ShapeConfig, *, multi_pod: bool,
               smoke: bool = False, microbatches: Optional[int] = None,
               fsdp: Optional[bool] = None, donate: bool = True,
               pure_dp: bool = False, unroll_decode: bool = False,
               opt_dtype: str = "float32", shard_stash: bool = False,
               tag: str = "baseline") -> Dict[str, Any]:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))

    if unroll_decode and shape.kind == "decode":
        cfg = cfg.with_(scan_unroll=4096)   # full unroll of the layer scan

    params_shapes = _eval_shapes(lambda: lm_init(jax.random.key(0), cfg))
    n_params = float(sum(np.prod(l.shape) for l in jax.tree.leaves(params_shapes)))
    n_active = ha.active_param_count(params_shapes, cfg)
    if fsdp is None:
        fsdp = n_params > 8e9
    if pure_dp:
        fsdp = False
    # validated defaults for ≥8B cells (see §Perf iteration log):
    # bf16 Adam moments (args −28%) and model-sharded remat stash.
    if n_params > 8e9 and shape.kind == "train":
        if opt_dtype == "float32":
            opt_dtype = "bfloat16"
        shard_stash = True

    if pure_dp:
        # small-arch mode: replicate params, use EVERY mesh axis as data
        # parallelism (TP collectives for a <1B model dwarf its compute).
        from jax.sharding import NamedSharding, PartitionSpec as P
        all_axes = tuple(mesh.axis_names)
        p_sh = jax.tree.map(
            lambda l: NamedSharding(mesh, P(*([None] * len(l.shape)))),
            params_shapes)
        rules = {"act_btd": P(all_axes, None, None)}
    else:
        p_sh = partition.params_shardings(params_shapes, mesh, fsdp=fsdp)
        rules = partition.activation_rules(mesh)
        # EP buffer constraint only when the expert count divides the model
        # axis — otherwise the forced resharding of the dispatch scatter
        # REGRESSES memory (measured: grok-1 68→107 GiB; iteration log).
        if shard_stash:
            from jax.sharding import PartitionSpec as _P
            rules["act_stash"] = _P(partition.dp_axis_names(mesh), None,
                                    "model")
        # manual sharded embedding lookup (see transformer._embed_lookup)
        rules["__mesh__"] = mesh
        rules["embed_vocab_axis"] = "model"

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape.name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "params_b": n_params / 1e9,
        "active_params_b": n_active / 1e9, "fsdp": bool(fsdp),
        "tag": tag, "pure_dp": pure_dp,
        "schedule": cfg.attn_schedule,
    }

    from jax.sharding import NamedSharding, PartitionSpec as P
    dp_axes_all = tuple(mesh.axis_names) if pure_dp \
        else partition.dp_axis_names(mesh)

    def _batch_sh(batch_sds):
        if pure_dp:
            def one(l):
                sh = tuple(l.shape)
                n = partition.axis_size(mesh, dp_axes_all)
                spec = [None] * len(sh)
                if sh and sh[0] % n == 0:
                    spec[0] = dp_axes_all
                return NamedSharding(mesh, P(*spec))
            return jax.tree.map(one, batch_sds)
        return partition.to_shardings(
            partition.batch_pspecs(batch_sds, mesh), mesh)

    with mesh, sharding_rules(rules):
        if shape.kind == "train":
            mb = microbatches or pick_microbatches(cfg, shape, mesh)
            rec["microbatches"] = mb
            opt_cfg = OptConfig()
            _mdt = jnp.bfloat16 if opt_dtype == "bfloat16" else jnp.float32
            opt_shapes = _eval_shapes(
                lambda p: adamw_init(p, moment_dtype=_mdt), params_shapes)
            if pure_dp:
                o_sh = jax.tree.map(
                    lambda l: NamedSharding(mesh, P(*([None] * len(l.shape)))),
                    opt_shapes)
                g_pspecs = jax.tree.map(
                    lambda l: P(*([None] * len(l.shape))), params_shapes)
            else:
                o_sh = partition.to_shardings(
                    partition.opt_state_pspecs(opt_shapes, params_shapes,
                                               mesh, fsdp=fsdp), mesh)
                g_pspecs = partition.params_pspecs(params_shapes, mesh,
                                                   fsdp=fsdp)
            batch_sds = train_input_specs(cfg, shape)
            b_sh = _batch_sh(batch_sds)
            step = make_train_step(cfg, opt_cfg, microbatches=mb,
                                   grad_pspecs=g_pspecs)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_shapes, opt_shapes, batch_sds)
        elif shape.kind == "prefill":
            batch_sds = train_input_specs(cfg, shape)
            b_sh = _batch_sh(batch_sds)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_shapes, batch_sds)
        else:  # decode
            specs = decode_input_specs(cfg, shape)
            c_sh = partition.to_shardings(
                partition.cache_pspecs(specs["caches"], mesh), mesh)
            tok_sh = partition.to_shardings(
                partition.batch_pspecs(specs["token"], mesh), mesh)
            step = make_decode_step(cfg)
            args = [params_shapes, specs["token"], specs["caches"],
                    specs["index"]]
            in_sh = [p_sh, tok_sh, c_sh, None]
            if "memory" in specs:
                args.append(specs["memory"])
                in_sh.append(partition.to_shardings(
                    partition.batch_pspecs(specs["memory"], mesh), mesh))
            jitted = jax.jit(step, in_shardings=tuple(in_sh),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(*args)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        # ---- memory analysis (per device) ----
        try:
            ma = compiled.memory_analysis()
            mem = {}
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(ma, f, None)
                if v is not None:
                    mem[f] = int(v)
            live = mem.get("argument_size_in_bytes", 0) \
                + mem.get("temp_size_in_bytes", 0) \
                + mem.get("output_size_in_bytes", 0) \
                - mem.get("alias_size_in_bytes", 0)
            mem["live_bytes"] = int(live)
            mem["fits_16g"] = bool(live < HBM_PER_CHIP)
            rec["memory"] = mem
        except Exception as e:  # pragma: no cover
            rec["memory_error"] = repr(e)

        # ---- HLO-static cost analysis (recorded for reference; while-loop
        # bodies are counted once by XLA, so these UNDERCOUNT scanned work
        # — see launch/flops.py docstring) ----
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["hlo_static_flops"] = float(ca.get("flops", 0.0))
            rec["hlo_static_bytes"] = float(ca.get("bytes accessed", 0.0))
        except Exception as e:  # pragma: no cover
            rec["cost_error"] = repr(e)

        # ---- collective schedule from the compiled artifact ----
        try:
            text = compiled.as_text()
            rec["collectives_static"] = ha.collective_bytes(text)
            rec["hlo_lines"] = text.count("\n")
        except Exception as e:  # pragma: no cover
            rec["collective_error"] = repr(e)

        # ---- analytic executed cost (primary; HLO-validated in tests) ----
        if pure_dp:
            dp_n, model_n = n_chips, 1
        else:
            dp_n = partition.axis_size(mesh, partition.dp_axis_names(mesh))
            model_n = partition.axis_size(mesh, "model")
        ac = aflops.analytic_cost(
            cfg, shape, dp_n=dp_n, model_n=model_n,
            microbatches=rec.get("microbatches", 1), fsdp=fsdp)
        rec["analytic"] = {
            "flops_per_device": ac.flops_per_device,
            "hbm_bytes_per_device": ac.hbm_bytes_per_device,
            "coll_bytes_per_device": ac.coll_bytes_per_device,
            "detail": {k: float(v) for k, v in ac.detail.items()},
        }
        mf = ha.model_flops(cfg, shape, n_active)
        rec["model_flops"] = mf
        rl = ha.roofline_terms(
            hlo_flops=ac.flops_per_device, hlo_bytes=ac.hbm_bytes_per_device,
            coll_bytes=ac.coll_bytes_per_device, model_flops=mf)
        rec["roofline"] = {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "useful_flop_fraction": rl.useful_flop_fraction(n_chips),
            "roofline_fraction": rl.roofline_fraction(n_chips),
        }
    rec["ok"] = True
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (machinery validation)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--shard-stash", action="store_true",
                    help="model-shard the period-boundary remat stash")
    ap.add_argument("--tag", default="baseline",
                    help="label recorded per cell (perf-iteration log)")
    ap.add_argument("--pure-dp", action="store_true",
                    help="replicate params; all mesh axes as DP (small archs)")
    ap.add_argument("--unroll-decode", action="store_true",
                    help="fully unroll the layer scan in decode cells")
    ap.add_argument("--schedule", default=None, choices=[None, "rect", "tri"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--device-limited", type=int, default=0,
                    help="top-M expert device groups per token (deepseek-v2)")
    ap.add_argument("--sparse-ffn", action="store_true",
                    help="relu2 FFN through the paper's sparse-bwd units")
    args = ap.parse_args()

    table = SMOKE_ARCHS if args.smoke else ARCHS
    archs = list(table) if args.arch == "all" else args.arch.split(",")
    shapes = {s.name: s for s in ALL_SHAPES}
    sel_shapes = list(shapes.values()) if args.shape == "all" \
        else [shapes[s] for s in args.shape.split(",")]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    fsdp = None if args.fsdp is None else (args.fsdp == "on")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = args.out or os.path.join(
        RESULTS_DIR, f"dryrun{'_smoke' if args.smoke else ''}.jsonl")

    n_ok = n_fail = n_skip = 0
    with open(out_path, "a") as f:
        for arch in archs:
            cfg = table[arch]
            if args.schedule:
                cfg = cfg.with_(attn_schedule=args.schedule)
            if args.capacity_factor is not None and cfg.moe is not None:
                import dataclasses as _dc
                cfg = cfg.with_(moe=_dc.replace(
                    cfg.moe, capacity_factor=args.capacity_factor))
            if args.device_limited and cfg.moe is not None:
                import dataclasses as _dc
                cfg = cfg.with_(moe=_dc.replace(
                    cfg.moe, device_groups=16,
                    top_groups=args.device_limited))
            if args.sparse_ffn:
                cfg = cfg.with_(ffn_activation="relu2",
                                sparse_ffn_scenario="IN_OUT")
            full_cfg = ARCHS[arch]          # applicability uses real arch
            for shape in sel_shapes:
                ok, why = runs_cell(full_cfg, shape)
                for multi_pod in meshes:
                    tag = f"{arch} × {shape.name} × {'2x16x16' if multi_pod else '16x16'}"
                    if not ok:
                        rec = {"arch": arch, "shape": shape.name,
                               "mesh": "2x16x16" if multi_pod else "16x16",
                               "skipped": True, "reason": why}
                        print(f"[skip] {tag}: {why}")
                        n_skip += 1
                    else:
                        try:
                            rec = lower_cell(
                                arch, cfg, shape, multi_pod=multi_pod,
                                smoke=args.smoke,
                                microbatches=args.microbatches, fsdp=fsdp,
                                pure_dp=args.pure_dp,
                                unroll_decode=args.unroll_decode,
                                opt_dtype=args.opt_dtype,
                                shard_stash=args.shard_stash,
                                tag=args.tag)
                            r = rec["roofline"]
                            live_gib = rec.get("memory", {}) \
                                .get("live_bytes", 0) / 2**30
                            rf = r["roofline_fraction"]
                            print(f"[ok]   {tag}: compile={rec['compile_s']}s "
                                  f"live={live_gib:.2f}GiB "
                                  f"dominant={r['dominant']} "
                                  f"rf={rf and round(rf, 3)}")
                            n_ok += 1
                        except Exception as e:
                            rec = {"arch": arch, "shape": shape.name,
                                   "mesh": "2x16x16" if multi_pod else "16x16",
                                   "ok": False, "error": repr(e),
                                   "traceback": traceback.format_exc()[-2000:]}
                            print(f"[FAIL] {tag}: {e!r}")
                            n_fail += 1
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
    print(f"\ndone: {n_ok} ok, {n_fail} failed, {n_skip} skipped → {out_path}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
