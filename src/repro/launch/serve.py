"""Serving entry point: batched decode over a (smoke or full) arch."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.models.transformer import lm_init
from repro.serving.engine import GenRequest, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    engine = ServeEngine(cfg, params, batch_slots=args.slots, max_len=256)
    rng = jax.random.key(1)
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (6,), 0, cfg.vocab_size).tolist()
        engine.submit(GenRequest(rid, prompt, max_tokens=args.max_tokens))
    done = engine.run()
    for rid in sorted(done):
        print(f"req {rid}: {done[rid]}")
    print(f"served {len(done)}/{args.requests} requests "
          f"in {engine.index} engine ticks")


if __name__ == "__main__":
    main()
