"""Analytic executed-cost model: FLOPs / HBM bytes / collective bytes.

WHY ANALYTIC: XLA's HLO cost analysis does not multiply ``while``-body
costs by trip count, so any scanned program (layer stack, microbatch
accumulation, kv-chunk attention, SSM chunk scan) is undercounted by
orders of magnitude in ``compiled.cost_analysis()``.  This module counts
the executed work from the architecture itself.  It is validated against
``cost_analysis()`` on small FULLY-UNROLLED configs in
tests/test_flops_model.py (agreement asserted), then trusted for the full
cells where unrolling is impossible.

Granularity and conventions (documented for EXPERIMENTS.md):
  * matmul FLOPs are derived from the parameter tree itself: every weight
    leaf contributes 2·prod(shape) FLOPs per token that passes through it
    (exactly how the layers use them); MoE expert leaves are scaled by
    top_k·capacity_factor/n_experts (capacity dispatch computes that
    fraction); embed gathers are 0 FLOPs; tied heads add 2·d·V.
  * attention score/value FLOPs are 4·B·T·S_eff·H·Dh with S_eff set by the
    *schedule actually lowered* (rect = full S; tri = causal prefix;
    window = clipped) — this is what makes the §Perf attention iterations
    measurable.
  * backward factor: fwd(1) + bwd(2) + remat re-fwd(1 if remat) — per
    paper-standard accounting.
  * HBM/collective byte models use named coefficients (ACT_RW_COEF etc.);
    they are estimates of traffic that XLA does not expose statically, and
    are held fixed across all §Perf iterations so deltas are meaningful.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4

ACT_RW_COEF = 16          # act HBM traffic ≈ coef · L · tokens · d · 2B (train)
ACT_RW_COEF_FWD = 6       # prefill/forward-only
WEIGHT_PASSES_TRAIN = 3   # fwd + bwd + remat re-read per microbatch
OPT_BYTES_PER_PARAM = 40  # master/mu/nu rw (f32) + grad rw
TP_COLLECTIVES_PER_LAYER = 2   # megatron-style per-layer activation syncs


def _norm(pstr: str) -> str:
    return pstr.replace("']['", "/").replace("['", "").replace("']", "")


@dataclasses.dataclass
class CostTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    detail: Dict[str, float]


def _param_groups(cfg: ModelConfig):
    """Split the param tree into (enc, dec, head, embed, expert-scaled)."""
    from repro.models.transformer import lm_init
    shapes = jax.eval_shape(lambda: lm_init(jax.random.key(0), cfg))
    groups = {"dec": 0.0, "enc": 0.0, "cross_kv": 0.0, "head": 0.0,
              "expert_frac": 0.0, "total": 0.0}
    period = len(cfg.pattern)
    n_periods = (cfg.n_layers - cfg.n_dense_layers) // period
    moe = cfg.moe
    for kp, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        path = _norm(jax.tree_util.keystr(kp))
        n = float(np.prod(leaf.shape))
        groups["total"] += n
        if re.search(r"(norm|scale|bias|b_i$|b_f$|dt_bias|d_skip|a_log)", path):
            continue
        if path == "embed":
            if cfg.tie_embeddings:
                groups["head"] += n
            continue
        if path == "lm_head":
            groups["head"] += n
            continue
        target = "enc" if path.startswith("encoder") else "dec"
        if "cross/wk" in path or "cross/wv" in path:
            target = "cross_kv"
        if re.search(r"moe/.*w_(gate|up|down)", path):
            frac = moe.top_k * moe.capacity_factor / moe.n_experts
            groups[target] += n * frac
        else:
            groups[target] += n
    return groups


def _attn_s_eff(t: int, s: int, cfg: ModelConfig, kind: str) -> float:
    """Effective scanned KV length per query token under the lowered
    schedule."""
    kc = min(cfg.kv_chunk, s)
    qc = min(cfg.q_chunk, t)
    nk = -(-s // kc)
    nq = -(-t // qc)
    if cfg.attn_schedule == "tri":
        # q-chunk i scans ceil((i+1)qc/kc) kv chunks
        tot = sum(min(nk, -(-((i + 1) * qc) // kc)) * kc for i in range(nq))
        s_eff = tot / nq
    else:
        s_eff = nk * kc
    if kind == "L" and cfg.sliding_window and cfg.attn_schedule == "win":
        # window schedule (perf variant): only chunks inside the window
        s_eff = min(s_eff, cfg.sliding_window + qc)
    return float(s_eff)


def _layer_kind_counts(cfg: ModelConfig) -> Dict[str, int]:
    kinds = cfg.layer_kinds()
    out: Dict[str, int] = {}
    for k in kinds:
        out[k] = out.get(k, 0) + 1
    out["moe_layers"] = sum(cfg.layer_uses_moe(i) for i in range(cfg.n_layers))
    return out


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig, *, dp_n: int,
                  model_n: int, microbatches: int = 1, fsdp: bool = False
                  ) -> CostTerms:
    n_chips = dp_n * model_n
    groups = _param_groups(cfg)
    kinds = _layer_kind_counts(cfg)
    d = cfg.d_model
    hq, dh = cfg.n_heads, cfg.head_dim_ if not cfg.use_mla else cfg.qk_nope_dim
    b, t = shape.global_batch, shape.seq_len
    detail: Dict[str, float] = {}

    train = shape.kind == "train"
    prefill = shape.kind == "prefill"
    decode = shape.kind == "decode"
    bwd_factor = (4.0 if cfg.remat else 3.0) if train else 1.0

    if cfg.enc_dec:
        tokens_dec = b * (t // 2)
        tokens_enc = b * (t // 2)
    elif cfg.frontend:
        tokens_dec = b * t          # frontend tokens flow through the trunk
        tokens_enc = 0
    else:
        tokens_dec = b * t
        tokens_enc = 0
    if decode:
        tokens_dec, tokens_enc = b, 0

    # ---------------- matmul FLOPs (param-tree-driven) ----------------
    mm = 2.0 * (groups["dec"] * tokens_dec + groups["enc"] * tokens_enc
                + groups["cross_kv"] * tokens_enc)
    mm_head = 2.0 * groups["head"] * (tokens_dec if not decode else b)
    if decode:
        mm = 2.0 * groups["dec"] * b + 2.0 * groups["cross_kv"] * 0
    detail["matmul_flops"] = mm * bwd_factor
    detail["head_flops"] = mm_head * bwd_factor

    # ---------------- attention score/value FLOPs ----------------
    attn_f = 0.0
    v_dim = cfg.v_head_dim if cfg.use_mla else cfg.head_dim_
    qk_dim = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.use_mla else cfg.head_dim_
    for kind in ("A", "G", "L"):
        n_l = kinds.get(kind, 0)
        if not n_l:
            continue
        if decode:
            s_ctx = min(cfg.sliding_window, t) if (kind == "L" and
                                                   cfg.sliding_window) else t
            attn_f += n_l * 2.0 * b * hq * s_ctx * (qk_dim + v_dim)
        else:
            s_eff = _attn_s_eff(t if not cfg.enc_dec else t // 2,
                                t if not cfg.enc_dec else t // 2, cfg, kind)
            tok = tokens_dec if not cfg.enc_dec else tokens_dec
            attn_f += n_l * 2.0 * tok * hq * s_eff * (qk_dim + v_dim) * bwd_factor
    if cfg.enc_dec and not decode:
        # encoder self-attn (bidirectional, rect) + decoder cross-attn
        attn_f += cfg.n_enc_layers * 4.0 * tokens_enc * hq * (t // 2) \
            * cfg.head_dim_ * bwd_factor
        attn_f += cfg.n_layers * 4.0 * tokens_dec * hq * (t // 2) \
            * cfg.head_dim_ * bwd_factor
    if cfg.enc_dec and decode:
        attn_f += cfg.n_layers * 4.0 * b * hq * 4096 * cfg.head_dim_  # cross
    detail["attn_flops"] = attn_f

    # ---------------- recurrent-block extras ----------------
    rec_f = 0.0
    if kinds.get("M"):
        di, ds = cfg.ssm_expand * d, cfg.ssm_d_state
        per_tok = 14.0 * di * ds + 10.0 * di
        rec_f += kinds["M"] * per_tok * (tokens_dec if not decode else b) \
            * (bwd_factor if not decode else 1.0)
    if kinds.get("m"):
        xc = cfg.xlstm_config()
        ch, hd, nh = (xc.chunk if not decode else 1), xc.head_dim_m, cfg.n_heads
        per_tok = nh * (4.0 * ch * hd + 4.0 * hd * hd + 6.0 * ch)
        rec_f += kinds["m"] * per_tok * (tokens_dec if not decode else b) \
            * (bwd_factor if not decode else 1.0)
    if kinds.get("s"):
        per_tok = 30.0 * d
        rec_f += kinds["s"] * per_tok * (tokens_dec if not decode else b) \
            * (bwd_factor if not decode else 1.0)
    detail["recurrent_flops"] = rec_f

    # ---------------- elementwise + loss + optimizer ----------------
    ew = 20.0 * d * cfg.n_layers * (tokens_dec if not decode else b) \
        * (bwd_factor if not decode else 1.0)
    loss_f = (4.0 * cfg.vocab_size * tokens_dec * (2.0 if train else 1.0)) \
        if not decode else 4.0 * cfg.vocab_size * b
    opt_f = 12.0 * groups["total"] if train else 0.0
    detail["elementwise_flops"] = ew
    detail["loss_flops"] = loss_f
    detail["opt_flops"] = opt_f

    total_flops = (detail["matmul_flops"] + detail["head_flops"] + attn_f
                   + rec_f + ew + loss_f + opt_f)
    flops_per_device = total_flops / n_chips

    # ---------------- HBM bytes (per device) ----------------
    p_total = groups["total"]
    shard_factor = model_n * (dp_n if fsdp else 1)
    p_res_bytes = p_total * BF16 / shard_factor
    period = len(cfg.pattern)
    n_periods = max(1, (cfg.n_layers - cfg.n_dense_layers) // period)
    tokens_loc = (tokens_dec + tokens_enc) / dp_n if not decode \
        else max(b // dp_n, 1)
    if train:
        w_traffic = WEIGHT_PASSES_TRAIN * microbatches * p_res_bytes
        opt_traffic = OPT_BYTES_PER_PARAM * p_total / shard_factor
        act_traffic = ACT_RW_COEF * cfg.n_layers * tokens_loc * (d / model_n
                                                                 + d) / 2 * BF16
        hbm = w_traffic + opt_traffic + act_traffic
        detail.update(w_traffic=w_traffic, opt_traffic=opt_traffic,
                      act_traffic=act_traffic)
    elif prefill:
        hbm = p_res_bytes + ACT_RW_COEF_FWD * cfg.n_layers * tokens_loc \
            * d * BF16
    else:
        # decode: weights + cache traffic dominate
        cache_bytes = 0.0
        s_ctx = t
        kv_heads = cfg.n_kv_heads
        for kind, cnt in (("A", kinds.get("A", 0)), ("G", kinds.get("G", 0)),
                          ("L", kinds.get("L", 0))):
            if not cnt:
                continue
            s_k = min(cfg.sliding_window, s_ctx) if (kind == "L" and
                                                     cfg.sliding_window) else s_ctx
            if cfg.use_mla:
                per_tok_layer = (cfg.kv_lora_rank + cfg.qk_rope_dim) * BF16
            else:
                per_tok_layer = 2 * kv_heads * cfg.head_dim_ * BF16
            cache_bytes += cnt * max(b // dp_n, 1) * s_k * per_tok_layer / \
                (model_n if kv_heads % model_n == 0 or cfg.use_mla else 1)
        state_bytes = 0.0
        if kinds.get("M"):
            state_bytes += kinds["M"] * max(b // dp_n, 1) * \
                (cfg.ssm_expand * d) * cfg.ssm_d_state * F32 * 2 / model_n
        if kinds.get("m"):
            xc = cfg.xlstm_config()
            state_bytes += kinds["m"] * max(b // dp_n, 1) * cfg.n_heads \
                * xc.head_dim_m ** 2 * F32 * 2
        hbm = p_res_bytes + cache_bytes + state_bytes
        detail.update(cache_traffic=cache_bytes, state_traffic=state_bytes,
                      w_traffic=p_res_bytes)

    # ---------------- collective bytes (per device) ----------------
    coll = 0.0
    if model_n > 1:
        tok_tp = tokens_loc if not decode else max(b // dp_n, 1)
        coll_tp = TP_COLLECTIVES_PER_LAYER * 2.0 * cfg.n_layers * tok_tp \
            * d * BF16 * (2.0 if train else 1.0)
        coll += coll_tp
        detail["coll_tp"] = coll_tp
    if train and dp_n > 1:
        if fsdp:
            ag = microbatches * WEIGHT_PASSES_TRAIN * p_total * BF16 / model_n
            rs = p_total * F32 / model_n
            coll += ag + rs
            detail["coll_fsdp"] = ag + rs
        else:
            ar = 2.0 * p_total * F32 / model_n
            coll += ar
            detail["coll_dp_ar"] = ar
    if cfg.moe is not None and model_n > 1 and not decode:
        # per MoE layer: dispatch + combine a2a (2×), each way (2×), ×2 bwd
        a2a = kinds["moe_layers"] * 4.0 * tokens_loc * cfg.moe.top_k \
            * cfg.moe.capacity_factor * d * BF16 * (2.0 if train else 1.0)
        if cfg.moe.device_groups and cfg.moe.top_groups:
            # device-limited routing bounds each token's expert fan-out to
            # top_groups shards (of min(top_k, device_groups) otherwise)
            a2a *= cfg.moe.top_groups / min(cfg.moe.top_k,
                                            cfg.moe.device_groups)
        coll += a2a
        detail["coll_ep_a2a"] = a2a

    return CostTerms(
        flops_per_device=flops_per_device,
        hbm_bytes_per_device=hbm,
        coll_bytes_per_device=coll,
        detail=detail,
    )
