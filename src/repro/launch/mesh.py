"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces a 512-device
host platform while tests/benches must see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod ("data","model"); multi_pod adds a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CI-scale sharding tests (requires ≥ n_data·n_model
    host devices, typically via --xla_force_host_platform_device_count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
