"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces a 512-device
host platform while tests/benches must see the real single device.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import AbstractMesh


def make_abstract_mesh(shape: Sequence[int],
                       axes: Sequence[str]) -> AbstractMesh:
    """Device-free AbstractMesh from parallel (shape, axes) sequences.

    jax's ``AbstractMesh`` constructor takes a single tuple of
    ``(axis_name, size)`` pairs (and has changed signature across jax
    releases) — this helper is the ONE place that knows that, so tests and
    library code agree on a construction API mirroring ``jax.make_mesh``.
    """
    assert len(shape) == len(axes), (shape, axes)
    try:
        # jax <= 0.4.x: AbstractMesh(shape_tuple) of (name, size) pairs.
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        # jax >= 0.5: AbstractMesh(axis_sizes, axis_names).
        return AbstractMesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod ("data","model"); multi_pod adds a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CI-scale sharding tests (requires ≥ n_data·n_model
    host devices, typically via --xla_force_host_platform_device_count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
