"""Step-function factories: train / prefill / decode.

These close over (ModelConfig, OptConfig) and expose pure functions with
(params, opt_state, batch)-style signatures suitable for jit with explicit
in/out shardings — used identically by the real trainer, the examples and
the 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step as _decode_step
from repro.models.transformer import encode, lm_head_weight, lm_hidden, lm_loss
from repro.optim.optimizer import OptConfig, adamw_update


def _split_microbatches(batch: Dict[str, jnp.ndarray], k: int):
    def r(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    *, microbatches: int = 1, grad_pspecs=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation over ``microbatches`` via lax.scan; the DP
    all-reduce of each microbatch's gradients is deferred to the final
    (sharding-induced) psum, which XLA schedules asynchronously against
    the next microbatch's compute (overlap).

    ``grad_pspecs``: PartitionSpec tree matching params.  Without it the
    compiler may materialize the f32 grad accumulator REPLICATED across
    the model/fsdp axes (measured: +45 GiB/device on grok-1-314b); with
    it the accumulator is pinned to the parameter sharding.
    """

    def _pin(g):
        if grad_pspecs is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            g, grad_pspecs)

    def loss_fn(params, mb):
        loss = lm_loss(params, mb, cfg)
        if opt_cfg.loss_scale > 0:
            return loss * opt_cfg.loss_scale, loss
        return loss, loss

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (_, loss), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = _pin(grads)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def body(acc, mb):
                gsum, lsum = acc
                (_, loss), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (_pin(gsum), lsum + loss), None

            g0 = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, lsum), _ = jax.lax.scan(body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = lsum / microbatches
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> last-position logits (B, V).

    Lowered for the prefill_32k cells: the full-sequence trunk dominates;
    cache write-out is the decode path's concern (noted in DESIGN.md).
    """

    def prefill_step(params, batch):
        memory = None
        fe = batch.get("frontend_embeds")
        if cfg.enc_dec:
            memory = encode(params, fe, cfg)
            fe = None
        h, _ = lm_hidden(params, batch["tokens"][:, :-1], cfg,
                         frontend_embeds=fe, memory=memory)
        logits = h[:, -1].astype(jnp.float32) @ \
            lm_head_weight(params, cfg).astype(jnp.float32)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, token, caches, index[, memory]) -> (logits, caches)."""

    def decode(params, token, caches, index, memory=None):
        return _decode_step(params, token, caches, index, cfg, memory=memory)

    return decode
