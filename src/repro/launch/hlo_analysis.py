"""Post-compile HLO analysis: collective bytes, roofline terms.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but not collective
traffic, so we parse the partitioned module text and sum the output-shape
bytes of every collective op (shapes are already per-device after SPMD
partitioning).  Roofline terms use the v5e-class constants from the brief:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# --- hardware constants (TPU v5e-class, per chip) ---
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape token: dtype[d0,d1,...]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# HLO op line:  %name = <type> opcode(
_OP_RE = re.compile(
    r"=\s+(\(?[\w\[\],\{\}\s/#*]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\-a-z]*\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective category (output-shape accounting)."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        type_str, opcode = m.group(1), m.group(2)
        out[opcode] += _shape_bytes(type_str)
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_total: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def useful_flop_fraction(self, n_chips: int) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        if not self.model_flops_total:
            return None
        return self.model_flops_total / (self.hlo_flops_per_device * n_chips)

    def roofline_fraction(self, n_chips: int) -> Optional[float]:
        """useful FLOPs / (chips × peak × bound-time) — the §Perf score."""
        if not self.model_flops_total or self.bound_s <= 0:
            return None
        return self.model_flops_total / (n_chips * PEAK_FLOPS * self.bound_s)


def roofline_terms(*, hlo_flops: float, hlo_bytes: float,
                   coll_bytes: float, model_flops: Optional[float] = None
                   ) -> Roofline:
    """All inputs are PER-DEVICE (post-SPMD shapes); model_flops is global."""
    return Roofline(
        compute_s=hlo_flops / PEAK_FLOPS,
        memory_s=hlo_bytes / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        hlo_flops_per_device=hlo_flops,
        hlo_bytes_per_device=hlo_bytes,
        collective_bytes_per_device=coll_bytes,
        model_flops_total=model_flops,
    )


def model_flops(cfg, shape, n_active_params: float) -> float:
    """MODEL_FLOPS per the brief: 6·N·D (train) — N = active params.

    prefill: 2·N·D; decode: 2·N·(batch tokens per step)."""
    if shape.kind == "train":
        return 6.0 * n_active_params * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active_params * shape.seq_len * shape.global_batch
    return 2.0 * n_active_params * shape.global_batch


def active_param_count(params_shapes, cfg) -> float:
    """Total params, with MoE expert tensors scaled by top_k/n_experts."""
    import jax
    import numpy as np
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    moe = getattr(cfg, "moe", None)
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        n = float(np.prod(leaf.shape))
        if moe is not None and re.search(r"moe.*w_(gate|up|down)", path):
            n *= moe.top_k / moe.n_experts
        total += n
    return total
