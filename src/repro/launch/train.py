"""Fault-tolerant training driver.

Features exercised end-to-end by examples/train_lm.py:
  * jit train step with explicit param/opt/batch shardings (mesh optional —
    single-device runs skip sharding entirely),
  * gradient-accumulation microbatching,
  * atomic checkpointing every N steps, keep-last-k, --resume auto
    (restart-safe: data cursor is the step index, so the token stream
    resumes bit-identically),
  * elastic restart: checkpoints are mesh-agnostic; a restart may use a
    different device count (restore_resharded),
  * straggler mitigation hook: per-step wall-times feed an outlier
    detector; on a real fleet the callback triggers re-balancing (here it
    logs — the decision logic is what we can test without a fleet),
  * optional int8 error-feedback gradient compression (DP all-reduce),
  * opt-in guarded execution (docs/resilience.md): pass a
    ``runtime.guards.StepGuard`` and each step's health is folded into a
    verdict — bounded skips, rollback-to-checkpoint with backoff,
    schedule degradation.  Guarded runs sync the small metric scalars
    every step; unguarded runs keep the deferred-loss contract (no
    per-step device→host sync).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import lm_batch
from repro.kernels import autotune as _autotune
from repro.launch.steps import make_train_step
from repro.models.transformer import lm_init
from repro.optim.optimizer import OptConfig, adamw_init
from repro.runtime import faults as _faults
from repro.runtime.guards import StepGuard
from repro.sharding import partition, sharding_rules
from repro.sharding import spmd_step as _spmd


@dataclasses.dataclass
class StragglerDetector:
    """Flags steps slower than ``threshold``× the trailing median.

    On a multi-host fleet the flag triggers the WR analogue at the cluster
    level: reassigning that host's shard of the next batches (the paper's
    §4.6 policy, one level up).  Here we record decisions for inspection.

    Two past skews are deliberately designed out:

    * the current sample must NOT be part of the median it is judged
      against — with small histories one giant outlier dragged the median
      up enough to excuse itself (self-masking);
    * the first observed step is compile + execute, often 100×+ a steady
      step; seeding the history with it inflated the median so the first
      real stragglers passed.  ``skip_first`` drops it from the history
      entirely (it can't be a straggler — there's nothing to compare it
      to — and it must not become the baseline either).
    """
    window: int = 32
    threshold: float = 2.0
    min_history: int = 8
    skip_first: bool = True
    times: list = dataclasses.field(default_factory=list)
    flags: list = dataclasses.field(default_factory=list)
    _seen: int = 0

    def observe(self, step: int, dt: float) -> bool:
        self._seen += 1
        if self.skip_first and self._seen == 1:
            return False
        hist = self.times[-self.window:]          # trailing, EXCLUDING dt
        med = float(np.median(hist)) if hist else 0.0
        slow = len(hist) >= self.min_history and dt > self.threshold * med
        if slow:
            self.flags.append((step, dt, med))
        self.times.append(dt)
        if len(self.times) > self.window:
            # only the trailing window is ever read — an unbounded history
            # is a slow leak on week-long runs
            del self.times[:-self.window]
        return slow


def train_loop(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    *,
    batch_size: int,
    seq_len: int,
    steps: int,
    ckpt_dir: Optional[str] = None,
    resume: bool = True,
    mesh=None,
    fsdp: bool = False,
    log_every: int = 10,
    param_dtype=jnp.float32,
    on_metrics: Optional[Callable[[int, Dict[str, float]], None]] = None,
    guard: Optional[StepGuard] = None,
    loss_flush_steps: int = 4096,
    spmd: bool = False,
    collective_cutoff: float = _spmd.DEFAULT_CUTOFF,
) -> Dict[str, Any]:
    """Returns {'params', 'opt_state', 'losses', 'straggler', 'resumed_from'}.

    ``guard`` opts into guarded execution: each step's metrics feed
    ``StepGuard.observe_step``; a *rollback*/*degrade* verdict restores the
    newest intact checkpoint (degrade additionally demotes suspect specs
    down the schedule ladder), and unhealthy steps never produce
    checkpoints.  ``loss_flush_steps`` bounds the deferred-loss buffer:
    device loss values materialize to host floats in chunks of that many
    steps (one sync per chunk) instead of pinning every step's device
    value until the loop ends.

    ``spmd=True`` (requires ``mesh``) swaps the jit-partitioned step for
    the explicit ``shard_map`` step (sharding/spmd_step.py): params and
    optimizer state replicated, batch sharded over the data axes, and the
    gradient all-reduce bitmap-compressed through sharding/collectives
    with dense fallback above ``collective_cutoff`` union live fraction.
    Guarded execution, checkpointing and mesh-aware rollback compose
    unchanged — checkpoints stay mesh-agnostic (replicated state restores
    through the same ``restore_resharded`` path)."""
    if spmd:
        if mesh is None:
            raise ValueError("spmd=True requires a mesh")
        if tcfg.microbatches != 1:
            raise ValueError(
                "spmd mode: the mesh IS the data-parallel split; "
                "gradient-accumulation microbatching is the jit path's "
                "feature (use microbatches=1)")
    opt_cfg = OptConfig(
        learning_rate=tcfg.learning_rate, warmup_steps=tcfg.warmup_steps,
        total_steps=tcfg.total_steps, weight_decay=tcfg.weight_decay,
        beta1=tcfg.beta1, beta2=tcfg.beta2, grad_clip=tcfg.grad_clip,
        loss_scale=tcfg.loss_scale, emit_guard_stats=guard is not None)
    step_fn = None if spmd else make_train_step(
        cfg, opt_cfg, microbatches=tcfg.microbatches)

    params = lm_init(jax.random.key(tcfg.seed), cfg, dtype=param_dtype)
    opt_state = adamw_init(params)
    start_step = 0
    resumed_from = None

    def _shardings(params, opt_state):
        if spmd:
            # shard_map replicates params/opt across the mesh; restores
            # (including elastic ones from sharded checkpoints) land on
            # the replicated layout.
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(mesh, PartitionSpec())
            return {"params": jax.tree.map(lambda _: rep, params),
                    "opt": jax.tree.map(lambda _: rep, opt_state)}
        return {
            "params": partition.params_shardings(params, mesh, fsdp=fsdp),
            "opt": partition.to_shardings(
                partition.opt_state_pspecs(opt_state, params, mesh,
                                           fsdp=fsdp), mesh),
        }

    def _restore_latest(params, opt_state):
        """Newest intact checkpoint → (step, params, opt_state); the
        shapes/dtypes of the current values are the template."""
        state_tpl = {"params": params, "opt": opt_state}
        if mesh is not None:
            step, state = ckpt.restore_resharded(
                ckpt_dir, state_tpl, _shardings(params, opt_state))
        else:
            step, state = ckpt.restore(ckpt_dir, state_tpl)
        return step, state["params"], state["opt"]

    def _host_state(step):
        """The state.json resume payload: autotune cache + guard state —
        a restart re-enters with warm schedules and an intact ladder."""
        extra = {"step": step, "autotune": _autotune.export_state()}
        if guard is not None:
            extra["guard"] = guard.export_state()
        return extra

    if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
        start_step, params, opt_state = _restore_latest(params, opt_state)
        resumed_from = start_step
        host_state = ckpt.load_state(ckpt_dir, start_step)
        if host_state:
            _autotune.import_state(host_state.get("autotune") or {})
            if guard is not None and host_state.get("guard"):
                guard.import_state(host_state["guard"])

    if spmd:
        sh = _shardings(params, opt_state)
        params = jax.device_put(params, sh["params"])
        opt_state = jax.device_put(opt_state, sh["opt"])
        jitted = _spmd.make_spmd_train_step(cfg, opt_cfg, mesh,
                                            cutoff=collective_cutoff)
        import contextlib
        ctx = contextlib.nullcontext   # no partitioner hints inside shard_map
    elif mesh is not None:
        p_sh = partition.params_shardings(params, mesh, fsdp=fsdp)
        o_sh = partition.to_shardings(
            partition.opt_state_pspecs(opt_state, params, mesh, fsdp=fsdp),
            mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        rules = partition.activation_rules(mesh)
        ctx = lambda: sharding_rules(rules)
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        import contextlib
        ctx = contextlib.nullcontext

    losses: list = []                 # host floats, flushed chunkwise
    pending: list = []                # device values awaiting one sync
    detector = StragglerDetector()
    with (mesh if mesh is not None else _null()), ctx():
        for step in range(start_step, steps):
            batch = lm_batch(tcfg.seed, step, batch=batch_size,
                             seq_len=seq_len, vocab=cfg.vocab_size)
            # Fault-injection taps (runtime/faults.py): zero-cost
            # passthroughs unless the chaos harness armed these sites.
            params = _faults.tap("train:params", params, step=step)
            opt_state = _faults.tap("train:opt_state", opt_state, step=step)
            t0 = time.time()
            params, opt_state, metrics = jitted(params, opt_state, batch)
            # Do NOT materialize metrics here: float(metrics["loss"]) is a
            # device→host sync that stalls dispatch EVERY step, serializing
            # the loop and poisoning dt (it measures the sync, not the
            # step).  Keep losses as device values; sync only on steps that
            # actually read them — guarded runs opt into the per-step sync,
            # that is the cost of a verdict every step.
            dt = time.time() - t0
            slow = detector.observe(step, dt)
            pending.append(metrics["loss"])
            if len(pending) >= loss_flush_steps:
                # chunked materialization: one sync per chunk bounds the
                # number of live device values without a per-step stall
                losses.extend(float(l) for l in pending)
                pending.clear()
            verdict = "ok"
            host: Optional[Dict[str, float]] = None
            if guard is not None:
                host = {k: float(v) for k, v in metrics.items()}
                verdict = guard.observe_step(
                    step, loss=host.get("loss"),
                    grad_norm=host.get("grad_norm"),
                    skipped=host.get("skipped"))
            log_step = log_every and step % log_every == 0
            if on_metrics or log_step:
                if host is None:
                    host = {k: float(v) for k, v in metrics.items()}
                if on_metrics:
                    on_metrics(step, {**host, "time_s": dt, "straggler": slow,
                                      "verdict": verdict})
                if log_step:
                    print(f"step {step:5d} loss {host['loss']:8.4f} "
                          f"gnorm {host['grad_norm']:8.3f} "
                          f"lr {host['lr']:.2e} {dt*1e3:7.1f} ms"
                          + ("  [straggler]" if slow else "")
                          + ("  [skipped]" if host.get("skipped") else ""))
            if verdict in ("rollback", "degrade"):
                if verdict == "degrade":
                    # the ladder's last rung before giving up: demote every
                    # suspect spec one schedule down (compact → predicated
                    # → dense), then restore like a rollback
                    guard.degrade()
                if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
                    _, params, opt_state = _restore_latest(params, opt_state)
            if ckpt_dir and tcfg.checkpoint_every and \
                    (step + 1) % tcfg.checkpoint_every == 0 and \
                    verdict == "ok":
                # never checkpoint an unhealthy step — a rollback must have
                # an intact state to land on
                ckpt.save(ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state},
                          keep=tcfg.keep_checkpoints,
                          extra=_host_state(step + 1))
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state},
                  keep=tcfg.keep_checkpoints, extra=_host_state(steps))
    losses.extend(float(l) for l in pending)   # final chunk sync
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "straggler": detector, "resumed_from": resumed_from}


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main() -> None:
    import argparse
    from repro.configs import SMOKE_ARCHS, ARCHS
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    tcfg = TrainConfig(total_steps=args.steps, microbatches=args.microbatches,
                       checkpoint_every=max(10, args.steps // 5))
    out = train_loop(cfg, tcfg, batch_size=args.batch, seq_len=args.seq,
                     steps=args.steps, ckpt_dir=args.ckpt_dir,
                     resume=not args.no_resume)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(resumed_from={out['resumed_from']})")


if __name__ == "__main__":
    main()
