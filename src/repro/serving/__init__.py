from .engine import ServeEngine, GenRequest  # noqa: F401
