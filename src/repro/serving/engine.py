"""Batched decode engine: continuous-batching-style serving loop.

Requests are admitted into fixed batch slots; each engine step decodes one
token for every active slot (single jitted decode_step over the whole
batch).  Finished slots (EOS or max_tokens) are immediately refilled from
the queue — the standard continuous-batching discipline, with per-slot
position indices kept in a vectorized cache.

Simplification vs a production server: all slots share one cache-length
high-water mark (`index` is the max position across slots; per-slot
validity is enforced by masking on position), and prompts are prefilled
token-by-token through the decode path.  Bulk prefill is lowered
separately for the roofline cells (launch/steps.make_prefill_step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, init_caches


@dataclasses.dataclass
class GenRequest:
    request_id: int
    prompt: List[int]
    max_tokens: int = 16
    eos_token: Optional[int] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[GenRequest] = None
    prompt_cursor: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.max_len = max_len
        self.caches = init_caches(cfg, batch_slots, max_len, dtype)
        self.queue: List[GenRequest] = []
        self.done: Dict[int, List[int]] = {}
        self.index = 0
        self._step = jax.jit(
            lambda p, tok, c, i: decode_step(p, tok, c, i, cfg))
        self._tokens = np.zeros((batch_slots,), np.int32)

    def submit(self, req: GenRequest) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in self.slots:
            if slot.req is None and self.queue:
                slot.req = self.queue.pop(0)
                slot.prompt_cursor = 0
                slot.generated = []

    @property
    def active(self) -> bool:
        return bool(self.queue) or any(s.req is not None for s in self.slots)

    def step(self) -> None:
        """One engine tick: feed each slot its next token, decode, collect."""
        self._admit()
        feed = np.zeros((len(self.slots),), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            if slot.prompt_cursor < len(slot.req.prompt):
                feed[i] = slot.req.prompt[slot.prompt_cursor]
            else:
                feed[i] = slot.generated[-1] if slot.generated else 0
        logits, self.caches = self._step(
            self.params, jnp.asarray(feed), self.caches,
            jnp.asarray(self.index, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.index += 1
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            if slot.prompt_cursor < len(slot.req.prompt) - 1:
                slot.prompt_cursor += 1
                continue
            slot.prompt_cursor += 1
            slot.generated.append(int(nxt[i]))
            req = slot.req
            if len(slot.generated) >= req.max_tokens or \
                    (req.eos_token is not None and
                     slot.generated[-1] == req.eos_token):
                self.done[req.request_id] = list(slot.generated)
                slot.req = None

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        ticks = 0
        while self.active and ticks < max_ticks and self.index < self.max_len:
            self.step()
            ticks += 1
        return self.done
