"""Deterministic synthetic data pipelines (LM tokens, images).

Every batch is a pure function of (seed, step, shard) — fold_in-keyed PRNG
— so (a) restarts resume bit-identically from the checkpointed step cursor
with no data-state file, (b) different data-parallel shards draw disjoint
streams, (c) elastic re-sharding (different shard count after restart)
still yields a deterministic, non-overlapping assignment.

The LM stream is *learnable* (noisy affine token recurrence), so example
training drivers show real loss descent rather than flat noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    """Checkpointable cursor."""
    step: int = 0


def lm_batch(seed: int, step: int, *, batch: int, seq_len: int, vocab: int,
             shard_index: int = 0, shard_count: int = 1,
             noise: float = 0.05) -> Dict[str, jnp.ndarray]:
    """(B, T+1) int32 token batch for next-token training.

    Sequence model: x_{t+1} = (a·x_t + b) mod V with p=noise random
    replacement; (a, b, x_0) drawn per-example.  Deterministic in
    (seed, step, shard_index)."""
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.fold_in(jax.random.key(seed), step), shard_index), 7)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    b = batch // shard_count
    a = jax.random.randint(k1, (b, 1), 1, 17)
    c = jax.random.randint(k2, (b, 1), 0, vocab)
    x0 = jax.random.randint(k3, (b, 1), 0, vocab)
    t = jnp.arange(seq_len + 1)
    # closed form of the affine recurrence mod V (avoid sequential scan):
    # x_t = a^t x_0 + c·(a^t - 1)/(a - 1); compute iteratively in log space
    # is overkill — just scan (T is small for examples, lowering is a scan).
    def stepf(x, _):
        nxt = (a[:, 0] * x + c[:, 0]) % vocab
        return nxt, nxt
    _, xs = jax.lax.scan(stepf, x0[:, 0], None, length=seq_len)
    tokens = jnp.concatenate([x0, xs.T], axis=1)
    flip = jax.random.bernoulli(k4, noise, tokens.shape)
    rand = jax.random.randint(jax.random.fold_in(k4, 1), tokens.shape, 0, vocab)
    tokens = jnp.where(flip, rand, tokens).astype(jnp.int32)
    return {"tokens": tokens}


def image_batch(seed: int, step: int, *, batch: int, image_size: int,
                channels: int = 3, num_classes: int = 100,
                shard_index: int = 0, shard_count: int = 1
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Class-conditional gaussian-blob images (learnable), normalized to
    zero mean — which is what gives CNNs the ~50% ReLU sparsity the paper
    measures (§3.1 input-normalization argument)."""
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.key(seed), step), shard_index)
    k1, k2, k3 = jax.random.split(key, 3)
    b = batch // shard_count
    labels = jax.random.randint(k1, (b,), 0, num_classes)
    base = jax.random.normal(k2, (b, image_size, image_size, channels))
    # class-dependent low-frequency pattern
    freq = (labels[:, None].astype(jnp.float32) + 1) / num_classes
    xx = jnp.linspace(0, 3.14159 * 4, image_size)
    pat = jnp.sin(freq * xx[None, :])[:, None, :, None] \
        * jnp.cos(freq * xx[None, :])[:, :, None, None]
    img = (base * 0.5 + pat).astype(jnp.float32)
    img = img - img.mean(axis=(1, 2, 3), keepdims=True)
    return img, labels.astype(jnp.int32)


class LMSynthetic:
    """Iterator facade with a checkpointable step cursor."""

    def __init__(self, *, seed: int, batch: int, seq_len: int, vocab: int,
                 shard_index: int = 0, shard_count: int = 1,
                 state: Optional[DataState] = None):
        self.seed, self.batch, self.seq_len, self.vocab = seed, batch, seq_len, vocab
        self.shard_index, self.shard_count = shard_index, shard_count
        self.state = state or DataState()

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        b = lm_batch(self.seed, self.state.step, batch=self.batch,
                     seq_len=self.seq_len, vocab=self.vocab,
                     shard_index=self.shard_index,
                     shard_count=self.shard_count)
        self.state.step += 1
        return b


class ImageSynthetic:
    def __init__(self, *, seed: int, batch: int, image_size: int,
                 num_classes: int = 100, state: Optional[DataState] = None):
        self.seed, self.batch = seed, batch
        self.image_size, self.num_classes = image_size, num_classes
        self.state = state or DataState()

    def __iter__(self):
        return self

    def __next__(self):
        out = image_batch(self.seed, self.state.step, batch=self.batch,
                          image_size=self.image_size,
                          num_classes=self.num_classes)
        self.state.step += 1
        return out
