from .pipeline import (ImageSynthetic, LMSynthetic, DataState,  # noqa: F401
                       lm_batch, image_batch)
