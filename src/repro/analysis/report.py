"""Violation records shared by the three checkers (jaxpr / kernel / lint).

One flat record type so the CLI, the CI artifact (CSV/JSON) and the
``benchmarks/kernel_audit.contract_audit`` table all consume the same rows.
Violation codes are documented in docs/static_analysis.md; each checker
owns a disjoint code namespace so a report line is self-identifying.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import json
from typing import Iterable, List


@dataclasses.dataclass(frozen=True)
class Violation:
    checker: str          # "jaxpr" | "kernel" | "lint"
    code: str             # e.g. "RESCAN", "DOUBLE_WRITE", "LOOSE_KWARG"
    where: str            # layer name / kernel+tile / file:line
    message: str          # human-readable, one line
    workload: str = ""    # the traced workload / sanitized launch, if any

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


FIELDS = [f.name for f in dataclasses.fields(Violation)]


def to_json(violations: Iterable[Violation]) -> str:
    return json.dumps([v.as_row() for v in violations], indent=2)


def to_csv(violations: Iterable[Violation]) -> str:
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=FIELDS)
    w.writeheader()
    for v in violations:
        w.writerow(v.as_row())
    return buf.getvalue()


def format_table(violations: List[Violation], title: str = "violations") -> str:
    """Fixed-width text table (the CLI / benchmark rendering)."""
    if not violations:
        return f"{title}: NONE"
    rows = [FIELDS] + [[str(getattr(v, f)) for f in FIELDS]
                       for v in violations]
    widths = [max(len(r[i]) for r in rows) for i in range(len(FIELDS))]
    lines = [f"{title}: {len(violations)}"]
    for r in rows:
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
    return "\n".join(lines)
