"""AST lint enforcing the PR-5 GEMM API contract across the repo source.

The spec-driven redesign (docs/gemm_api.md) has one load-bearing social
contract: NOBODY outside ``kernels/`` re-grows the pre-redesign call style.
These rules make that machine-checked:

  SHIM_CALL      no ``masked_matmul`` / ``grouped_masked_matmul`` call
                 sites anywhere — the warn-once deprecation shims are
                 DELETED (PR 8); only the frozen-reference comparisons
                 (``ref.masked_matmul``, the pure-jnp oracle) stay allowed.
  LOOSE_KWARG    no caller outside ``kernels/`` threads the old loose
                 kwargs (``compact=``, ``queue_builder=``,
                 ``fuse_epilogue=``) through a call — schedule/queue/
                 epilogue selection belongs to ``SparsityPolicy`` /
                 ``GemmSpec`` construction only.
  CONV_FALLBACK  ``lax.conv_general_dilated`` may appear only in a function
                 that also counts it (``stats.record("conv:dense_fallback")``)
                 — the engine-escape hatch must stay auditable.
  STATS_KEY      literal ``stats.record`` keys must parse into the known
                 families; ``gemm:`` keys must be the normalized
                 ``gemm:<schedule>:<g>`` launch form.

``lint_source`` lints one source string (the mutation self-tests plant
violations through it); ``lint_paths`` walks directories.

A sanctioned exception is waived IN PLACE with ``# repro-lint: allow(CODE)``
on the flagged line or the line above it — e.g. a benchmark's dense
``conv_general_dilated`` reference oracle.  Waivers are rule-specific so a
waived line stays covered by every other rule.
"""
from __future__ import annotations

import ast
import os
import re
from typing import List, Optional, Sequence

from .report import Violation

SHIM_NAMES = {"masked_matmul", "grouped_masked_matmul"}
# Attribute bases under which a shim-spelled call is the REFERENCE oracle,
# not the deprecated orchestrator (kernels/ref.py's pure-jnp comparators).
REF_BASES = {"ref", "kref"}
LOOSE_KWARGS = {"compact", "queue_builder", "fuse_epilogue"}
# Call targets that legitimately take the "loose" names as constructor /
# replace fields: policy and spec construction IS the sanctioned home.
SPEC_CALLEES = {"SparsityPolicy", "GemmSpec", "with_", "replace",
                "gemm_spec", "dataclasses.replace"}
KNOWN_KEY_HEADS = {"encode", "scan", "scan_pallas", "emit", "queue", "gemm",
                   "conv",
                   # runtime guard layer (docs/resilience.md):
                   "guard", "registry", "fallback",
                   # sharded collectives (docs/sharding.md):
                   "collective",
                   # legacy heads normalized by stats._KEY_ALIASES:
                   "mm", "gmm", "grouped_mm"}
FALLBACK_KEY = "conv:dense_fallback"
_ALLOW_RE = re.compile(r"repro-lint:\s*allow\(([A-Z_, ]+)\)")


def _waivers(code: str):
    """{(rule, lineno)} suppressed by ``# repro-lint: allow(RULE)`` markers
    (a marker covers its own line and the one below it)."""
    out = set()
    for lineno, line in enumerate(code.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            for rule in m.group(1).replace(",", " ").split():
                out.add((rule, lineno))
                out.add((rule, lineno + 1))
    return out


def _callee_parts(func: ast.expr) -> List[str]:
    """Dotted name parts of a call target, innermost last; [] if dynamic."""
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    parts.reverse()
    return parts


def _record_key(call: ast.Call) -> Optional[str]:
    """The literal key of a ``stats.record(...)`` call, else None."""
    parts = _callee_parts(call.func)
    if not parts or parts[-1] != "record":
        return None
    if len(parts) >= 2 and parts[-2] not in ("stats",):
        return None
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


class _FunctionIndex(ast.NodeVisitor):
    """Maps every node to its innermost enclosing function def."""

    def __init__(self):
        self.owner = {}
        self._stack: List[ast.AST] = []

    def generic_visit(self, node):
        if self._stack:
            self.owner[node] = self._stack[-1]
        is_fn = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda))
        if is_fn:
            self._stack.append(node)
        super().generic_visit(node)
        if is_fn:
            self._stack.pop()


def lint_source(code: str, path: str = "<string>",
                in_kernels: Optional[bool] = None) -> List[Violation]:
    """Lint one source file's text.  ``in_kernels`` overrides the
    kernels/-exemption detection (derived from ``path`` by default)."""
    if in_kernels is None:
        norm = path.replace(os.sep, "/")
        in_kernels = "/kernels/" in norm or norm.startswith("kernels/")
    try:
        tree = ast.parse(code)
    except SyntaxError as e:
        return [Violation("lint", "SYNTAX", f"{path}:{e.lineno}", str(e))]

    idx = _FunctionIndex()
    idx.visit(tree)
    waived = _waivers(code)

    # Pre-index: per enclosing function, the literal stats.record keys.
    fn_keys = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            key = _record_key(node)
            if key is not None:
                fn_keys.setdefault(idx.owner.get(node), set()).add(key)

    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        where = f"{path}:{node.lineno}"
        parts = _callee_parts(node.func)
        name = parts[-1] if parts else ""
        base = parts[-2] if len(parts) >= 2 else ""

        # SHIM_CALL — deleted-orchestrator call site (no kernels/ allowance:
        # the shims are gone, so such a call breaks at runtime anywhere)
        if name in SHIM_NAMES and base not in REF_BASES \
                and ("SHIM_CALL", node.lineno) not in waived:
            out.append(Violation(
                "lint", "SHIM_CALL", where,
                f"call to removed kernels.ops.{name}; build a GemmSpec "
                f"and call sparse_gemm (docs/gemm_api.md)"))

        # LOOSE_KWARG — pre-redesign kwargs threaded outside kernels/
        if not in_kernels and name not in SPEC_CALLEES \
                and ("LOOSE_KWARG", node.lineno) not in waived:
            loose = sorted(kw.arg for kw in node.keywords
                           if kw.arg in LOOSE_KWARGS)
            if loose:
                out.append(Violation(
                    "lint", "LOOSE_KWARG", where,
                    f"{', '.join(loose)} passed to {name or '<dynamic>'}(); "
                    f"schedule/queue/epilogue selection belongs to "
                    f"SparsityPolicy/GemmSpec"))

        # CONV_FALLBACK — dense conv without the counted escape hatch
        if name == "conv_general_dilated" \
                and ("CONV_FALLBACK", node.lineno) not in waived:
            keys = fn_keys.get(idx.owner.get(node), set())
            if FALLBACK_KEY not in keys:
                out.append(Violation(
                    "lint", "CONV_FALLBACK", where,
                    f"lax.conv_general_dilated outside the counted fallback "
                    f"(enclosing function never records {FALLBACK_KEY!r})"))

        # STATS_KEY — literal counter keys must be well-formed
        key = _record_key(node)
        if key is not None \
                and ("STATS_KEY", node.lineno) not in waived:
            head, _, tail = key.partition(":")
            bad = head not in KNOWN_KEY_HEADS
            if not bad and head == "gemm":
                sched, _, g = tail.partition(":")
                bad = sched not in ("predicated", "compact", "dense") \
                    or not g.isdigit()
            if bad:
                out.append(Violation(
                    "lint", "STATS_KEY", where,
                    f"stats.record key {key!r} not in the normalized "
                    f"families (kernels/stats.py docstring)"))
    return out


def lint_paths(paths: Sequence[str],
               exclude: Sequence[str] = ()) -> List[Violation]:
    """Lint every ``*.py`` under the given files/directories."""
    out: List[Violation] = []
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, _dirs, names in os.walk(p):
            files.extend(os.path.join(root, n) for n in sorted(names)
                         if n.endswith(".py"))
    for f in sorted(files):
        norm = f.replace(os.sep, "/")
        if any(e in norm for e in exclude):
            continue
        with open(f, encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), f))
    return out
