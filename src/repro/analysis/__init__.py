"""Static bitmap-contract verifier (docs/static_analysis.md).

Three checkers, no kernel execution anywhere:

  * ``jaxpr_audit``      — lifecycle proof over traced training steps
  * ``kernel_sanitizer`` — shadow-memory re-execution of the Pallas kernels
  * ``lint``             — AST rules pinning the spec-driven GEMM API

``python -m repro.analysis --fail-on-violation`` runs all three (the CI
gate); ``benchmarks/kernel_audit.py`` renders the same rows as a table.
"""
from .jaxpr_audit import WORKLOADS, audit_fn, audit_jaxpr, audit_workloads
from .kernel_sanitizer import (
    run_compact_grouped,
    run_predicated_grouped,
    run_queue_builder,
    sanitize_all,
)
from .lint import lint_paths, lint_source
from .report import Violation, format_table, to_csv, to_json

__all__ = [
    "Violation",
    "WORKLOADS",
    "audit_fn",
    "audit_jaxpr",
    "audit_workloads",
    "format_table",
    "lint_paths",
    "lint_source",
    "run_compact_grouped",
    "run_predicated_grouped",
    "run_queue_builder",
    "sanitize_all",
    "to_csv",
    "to_json",
]
