"""Static lifecycle audit: prove the bitmap contract on a traced jaxpr.

``jax.make_jaxpr`` gives the full dataflow of a training step without
executing a kernel; the runtime leaves machine-readable breadcrumbs in it
via ``kernels.stats.lifecycle_scope`` (scope names survive into every
equation's ``source_info.name_stack``, including through jvp/transpose).
This module walks that jaxpr and checks, per activation:

  RESCAN           the same tensor is scanned/encoded for a bitmap more
                   than once per step (the paper's contract: ONE fused
                   encode per activation; every later mask is derived).
  UNDERIVED_MASK   an integer mask enters a GEMM dispatch without being
                   reachable from an encode/scan/derive/queue region
                   through pure bitmap arithmetic — i.e. somebody computed
                   sparsity metadata outside the sanctioned producers.
  DENSE_GEMM       a ``dot_general`` outside any ``sparse_gemm`` dispatch
                   region — dense compute leaked onto the hot path.
  DENSE_SCHEDULE   a dispatch region resolved to ``schedule="dense"`` while
                   the audit expects the Pallas path.
  CONV_FALLBACK    a ``conv_general_dilated`` on the traced path: inside
                   the counted fallback region it means a layer escaped the
                   engine; outside any region it is an uncounted dense conv.
  SPEC_UNRESOLVED  a ``sparse_gemm`` dispatch whose ``GemmSpec`` was not
                   resolved by ``SparsityPolicy.gemm_spec`` (trace-time
                   provenance via ``kernels.ops.collect_gemm_events``).
  COLLECTIVE_UNTAGGED  a cross-shard collective (psum/all_gather/…)
                   outside any ``repro:collective:*`` region — gradient
                   traffic crossed the mesh without going through
                   ``sharding/collectives``'s bitmap-aware entry points.

All checks apply INSIDE ``shard_map`` bodies too: the generic sub-jaxpr
descent picks up the ``shard_map`` equation's ``jaxpr`` param like any
pjit/cond/scan, so the one-encode-per-activation and mask-derivation
contracts are verified across the whole mesh (the body is traced once for
all shards — one encode in the jaxpr IS one encode per shard per step).

Violations are keyed by the innermost ``layer:<name>`` scope so reports
read per-layer.  See docs/static_analysis.md for the full code catalogue.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .report import Violation

# One lifecycle tag: repro:<kind>[:<detail>]:<seq>.  Tags never contain
# "/", "(" or ")", which is exactly what the name-stack string uses for
# nesting and transform wrappers — so this match always grabs a whole tag.
TAG_RE = re.compile(r"repro:[^/()]+")
LAYER_RE = re.compile(r"layer:[^/()]+")

# Primitives that forward bitmap/array content without computing anything
# new from it — a mask may flow through these on its way from a producer
# region to a consumer without breaking derivation provenance.
TRIVIAL_PRIMS = {
    "convert_element_type", "reshape", "transpose", "squeeze",
    "expand_dims", "broadcast_in_dim", "slice", "dynamic_slice", "pad",
    "concatenate", "copy", "stop_gradient", "rev",
}

# "collective" grounds masks too: the union bitmap a bitmap-psum produces
# is derived metadata (an OR across shards of already-grounded bitmaps).
GROUNDING_KINDS = {"encode", "scan", "derive", "queue", "collective"}

# Cross-shard primitives that move tensor data over the interconnect.
COLLECTIVE_PRIMS = {
    "psum", "psum2", "all_reduce", "all_gather", "all_to_all",
    "reduce_scatter", "ppermute", "pmax", "pmin",
}


@dataclasses.dataclass
class ParsedTag:
    kind: str          # encode | scan | derive | queue | gemm | fallback
    detail: str        # e.g. "act", "grad", "compact:1"
    tag: str           # the full unique tag (region identity)


def parse_tag(tag: str) -> ParsedTag:
    parts = tag.split(":")
    # repro:<kind>[:<detail>...]:<seq>
    return ParsedTag(kind=parts[1], detail=":".join(parts[2:-1]), tag=tag)


@dataclasses.dataclass
class EqnInfo:
    eqn: Any
    tag: Optional[ParsedTag]      # innermost lifecycle region, if any
    layer: str                    # innermost layer:<name> scope, or ""
    depth: int                    # sub-jaxpr nesting depth


class _Walk:
    """Flattened equation list over a closed jaxpr and its sub-jaxprs,
    with a best-effort var-aliasing map across jaxpr boundaries."""

    def __init__(self, closed_jaxpr):
        self.infos: List[EqnInfo] = []
        self.producer: Dict[Any, EqnInfo] = {}
        self.alias: Dict[Any, Any] = {}
        self._visit(closed_jaxpr.jaxpr, outer_stack="", depth=0)

    # -- var canonicalization across sub-jaxpr boundaries --
    def canon(self, v):
        seen = set()
        while v in self.alias and v not in seen:
            seen.add(v)
            v = self.alias[v]
        return v

    def _link(self, inner_vars, outer_vars):
        if len(inner_vars) != len(outer_vars):
            return  # unknown convention: leave unaliased (conservative)
        for iv, ov in zip(inner_vars, outer_vars):
            if type(iv).__name__ == "Var" and type(ov).__name__ == "Var":
                self.alias[iv] = ov

    @staticmethod
    def _sub_jaxprs(eqn):
        """(jaxpr, invars_of_eqn_feeding_it) pairs found in eqn params."""
        if eqn.primitive.name == "pallas_call":
            return []  # kernel-internal program: the sanitizer's domain
        subs = []

        def collect(v):
            core_jaxpr = getattr(v, "jaxpr", None)
            if core_jaxpr is not None and hasattr(core_jaxpr, "eqns"):
                subs.append(core_jaxpr)          # ClosedJaxpr
            elif hasattr(v, "eqns"):
                subs.append(v)                   # raw Jaxpr
            elif isinstance(v, (list, tuple)):
                for x in v:
                    collect(x)

        for v in eqn.params.values():
            collect(v)
        return subs

    def _visit(self, jaxpr, outer_stack: str, depth: int):
        for eqn in jaxpr.eqns:
            stack = outer_stack + "/" + str(eqn.source_info.name_stack)
            tags = TAG_RE.findall(stack)
            layers = LAYER_RE.findall(stack)
            info = EqnInfo(
                eqn=eqn,
                tag=parse_tag(tags[-1]) if tags else None,
                layer=layers[-1][len("layer:"):] if layers else "",
                depth=depth,
            )
            self.infos.append(info)
            for ov in eqn.outvars:
                self.producer[ov] = info
            for sub in self._sub_jaxprs(eqn):
                # Common conventions (pjit/closed_call/custom_*) line the
                # eqn invars up 1:1 with the sub-jaxpr invars; cond carries
                # the predicate first.  Anything else stays unaliased.
                inv = list(eqn.invars)
                if eqn.primitive.name == "cond" and inv:
                    inv = inv[1:]
                self._link(list(sub.invars), inv)
                self._link(list(eqn.outvars), list(sub.outvars))
                self._visit(sub, stack, depth + 1)


def _is_var(v) -> bool:
    return type(v).__name__ == "Var"


def _array_invars(eqn):
    return [v for v in eqn.invars if _is_var(v)]


def _region_map(infos: List[EqnInfo]) -> Dict[str, List[EqnInfo]]:
    regions: Dict[str, List[EqnInfo]] = {}
    for info in infos:
        if info.tag is not None:
            regions.setdefault(info.tag.tag, []).append(info)
    return regions


def _region_layer(eqns: List[EqnInfo]) -> str:
    for e in eqns:
        if e.layer:
            return e.layer
    return ""


def _principal_input(walk: _Walk, region: List[EqnInfo]):
    """The largest floating-point tensor a scan/encode region consumes from
    outside itself — the tensor being scanned."""
    region_ids = {id(e.eqn) for e in region}
    best, best_size = None, -1
    for info in region:
        for v in _array_invars(info.eqn):
            cv = walk.canon(v)
            prod = walk.producer.get(cv)
            if prod is not None and id(prod.eqn) in region_ids:
                continue
            aval = v.aval
            if not jnp.issubdtype(aval.dtype, jnp.floating):
                continue
            if aval.size > best_size:
                best, best_size = cv, aval.size
    return best


def _canonical_tensor(walk: _Walk, v):
    """Walk back through content-preserving reshapes/casts so the 'same
    tensor scanned twice' check is insensitive to trivial re-layout."""
    seen = set()
    while True:
        v = walk.canon(v)
        if v in seen:
            return v
        seen.add(v)
        prod = walk.producer.get(v)
        if prod is None:
            return v
        name = prod.eqn.primitive.name
        if name in ("convert_element_type", "reshape", "transpose",
                    "squeeze", "expand_dims", "copy", "stop_gradient"):
            ins = _array_invars(prod.eqn)
            if len(ins) == 1:
                v = ins[0]
                continue
        return v


def _check_rescan(walk: _Walk, regions, workload) -> List[Violation]:
    out = []
    seen: Dict[Any, Tuple[str, str]] = {}
    for tag, eqns in sorted(regions.items()):
        parsed = eqns[0].tag
        if parsed.kind not in ("scan", "encode"):
            continue
        src = _principal_input(walk, eqns)
        if src is None:
            continue
        src = _canonical_tensor(walk, src)
        layer = _region_layer(eqns)
        if src in seen:
            first_tag, first_layer = seen[src]
            out.append(Violation(
                "jaxpr", "RESCAN", layer or first_layer,
                f"tensor scanned twice: {parsed.kind} region {tag} re-scans "
                f"the input of region {first_tag} — derive the mask instead",
                workload))
        else:
            seen[src] = (tag, layer)
    return out


def _check_masks_derived(walk: _Walk, regions, workload) -> List[Violation]:
    out = []
    for tag, eqns in sorted(regions.items()):
        parsed = eqns[0].tag
        if parsed.kind != "gemm":
            continue
        layer = _region_layer(eqns)
        region_ids = {id(e.eqn) for e in eqns}
        # Integer inputs of the dispatch region = masks & queue metadata.
        int_inputs = []
        for info in eqns:
            for v in _array_invars(info.eqn):
                cv = walk.canon(v)
                prod = walk.producer.get(cv)
                if prod is not None and id(prod.eqn) in region_ids:
                    continue
                dt = v.aval.dtype
                if jnp.issubdtype(dt, jnp.integer) or dt == jnp.bool_:
                    int_inputs.append(cv)
        for v in dict.fromkeys(int_inputs):
            bad = _trace_mask_origin(walk, v, region_ids)
            if bad is not None:
                out.append(Violation(
                    "jaxpr", "UNDERIVED_MASK", layer,
                    f"mask entering dispatch {tag} originates in "
                    f"untagged op '{bad.eqn.primitive.name}' — sparsity "
                    f"metadata must come from encode/scan/derive/queue",
                    workload))
                break  # one report per dispatch is enough
    return out


def _trace_mask_origin(walk: _Walk, var, consumer_region_ids):
    """None if every path from ``var`` grounds in a sanctioned producer
    region / constant / jaxpr input; else the offending EqnInfo."""
    stack, seen = [var], set()
    while stack:
        v = walk.canon(stack.pop())
        if v in seen:
            continue
        seen.add(v)
        prod = walk.producer.get(v)
        if prod is None:
            continue  # top-level input or constant: provenance unknowable
        if id(prod.eqn) in consumer_region_ids:
            stack.extend(_array_invars(prod.eqn))   # dispatcher plumbing
            continue
        if prod.tag is not None and prod.tag.kind in GROUNDING_KINDS:
            continue                                 # grounded
        if prod.tag is not None and prod.tag.kind == "gemm":
            continue  # another dispatch's (checked) output
        name = prod.eqn.primitive.name
        ins = _array_invars(prod.eqn)
        if not ins:
            continue  # iota etc: index arithmetic, not scanned data
        if name in TRIVIAL_PRIMS or all(
                jnp.issubdtype(i.aval.dtype, jnp.integer)
                or i.aval.dtype == jnp.bool_ for i in ins):
            # Pure bitmap/index arithmetic: keep walking its inputs.
            stack.extend(ins)
            continue
        return prod  # computes int data from float tensors, untagged
    return None


def _check_dense_ops(walk: _Walk, workload,
                     expect_pallas: bool) -> List[Violation]:
    out = []
    for info in walk.infos:
        name = info.eqn.primitive.name
        kind = info.tag.kind if info.tag else None
        if name == "dot_general" and kind != "gemm":
            out.append(Violation(
                "jaxpr", "DENSE_GEMM", info.layer,
                f"dot_general outside any sparse_gemm dispatch region "
                f"(scope: {info.tag.tag if info.tag else '<none>'})",
                workload))
        if name == "conv_general_dilated":
            if kind == "fallback":
                out.append(Violation(
                    "jaxpr", "CONV_FALLBACK", info.layer,
                    "layer escaped the conv engine onto the counted dense "
                    "fallback", workload))
            else:
                out.append(Violation(
                    "jaxpr", "DENSE_CONV", info.layer,
                    "uncounted conv_general_dilated on the traced path",
                    workload))
        if expect_pallas and kind == "gemm" \
                and info.tag.detail.startswith("dense"):
            out.append(Violation(
                "jaxpr", "DENSE_SCHEDULE", info.layer,
                f"dispatch {info.tag.tag} resolved to schedule='dense' "
                f"under a Pallas-audited workload", workload))
    # One DENSE_SCHEDULE region spans many eqns: dedupe by region tag.
    deduped, seen = [], set()
    for v in out:
        key = (v.code, v.message)
        if key in seen:
            continue
        seen.add(key)
        deduped.append(v)
    return deduped


def _check_collectives(walk: _Walk, workload) -> List[Violation]:
    """Every cross-shard data movement must sit in a collective region:
    an untagged psum is gradient traffic that bypassed the bitmap-aware
    all-reduce (and with it the compression, the stats keys, and the
    fault-injection tap)."""
    out, seen = [], set()
    for info in walk.infos:
        if info.eqn.primitive.name not in COLLECTIVE_PRIMS:
            continue
        if info.tag is not None and info.tag.kind == "collective":
            continue
        key = (info.layer, info.eqn.primitive.name,
               info.tag.tag if info.tag else "")
        if key in seen:
            continue
        seen.add(key)
        out.append(Violation(
            "jaxpr", "COLLECTIVE_UNTAGGED", info.layer,
            f"'{info.eqn.primitive.name}' outside any repro:collective "
            f"region (scope: {info.tag.tag if info.tag else '<none>'}) — "
            f"cross-shard traffic must go through sharding/collectives",
            workload))
    return out


def audit_jaxpr(closed_jaxpr, *, workload: str = "",
                expect_pallas: bool = True) -> List[Violation]:
    """Run every jaxpr-level check on an already-traced program."""
    walk = _Walk(closed_jaxpr)
    regions = _region_map(walk.infos)
    out: List[Violation] = []
    out += _check_rescan(walk, regions, workload)
    out += _check_masks_derived(walk, regions, workload)
    out += _check_dense_ops(walk, workload, expect_pallas)
    out += _check_collectives(walk, workload)
    return out


def audit_fn(fn, *args, workload: str = "",
             expect_pallas: bool = True) -> List[Violation]:
    """Trace ``fn(*args)`` (no execution) and audit the result, including
    the trace-time GemmSpec provenance check."""
    from repro.kernels import ops

    with ops.collect_gemm_events() as events:
        closed = jax.make_jaxpr(fn)(*args)
    out = audit_jaxpr(closed, workload=workload, expect_pallas=expect_pallas)
    for spec in events:
        if spec.origin != "policy":
            out.append(Violation(
                "jaxpr", "SPEC_UNRESOLVED", "",
                f"sparse_gemm dispatched with an ad-hoc GemmSpec "
                f"(origin={spec.origin!r}, schedule={spec.schedule!r}); "
                f"resolve specs through SparsityPolicy.gemm_spec",
                workload))
    return out


# ---------------------------------------------------------------------------
# Standard audited workloads — the zero-violation gate on main
# ---------------------------------------------------------------------------

def _audit_policy():
    from repro.core import policy as pol
    return pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))


def _cnn_step(name: str, *, image_size: int, width: float, batch: int = 2):
    from repro.models.cnn import build_cnn
    model = build_cnn(name, image_size=image_size, width=width,
                      num_classes=10)
    params = model.init(jax.random.PRNGKey(0))
    images = jnp.zeros((batch, image_size, image_size, 3), jnp.float32)
    labels = jnp.zeros((batch,), jnp.int32)
    policy = _audit_policy()

    def step(p):
        return model.loss(p, images, labels, policy)

    return (lambda: jax.grad(step)(params))


def _ffn_step(batch: int = 4):
    from repro.models.ffn import FFNConfig, ffn_apply, ffn_init
    cfg = FFNConfig(d_model=16, d_ff=32, activation="relu",
                    sparse_policy=_audit_policy())
    params = ffn_init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((batch, cfg.d_model), jnp.float32)

    def step(p):
        return (ffn_apply(p, x, cfg) ** 2).sum()

    return (lambda: jax.grad(step)(params))


def _spmd_mesh():
    """A 1-device mesh: the audit only TRACES, and a shard_map body's
    jaxpr is mesh-size-independent — so the contract verified here holds
    for any device count (the 8-device execution tests live in
    tests/test_sparse_collectives.py)."""
    return jax.make_mesh((1,), ("data",))


def _ffn_spmd_step(batch: int = 4):
    from repro.models.ffn import FFNConfig, ffn_apply, ffn_init
    from repro.sharding import spmd_step
    cfg = FFNConfig(d_model=16, d_ff=32, activation="relu",
                    sparse_policy=_audit_policy())
    params = ffn_init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((batch, cfg.d_model), jnp.float32)

    def loss_fn(p, xb):
        return (ffn_apply(p, xb, cfg) ** 2).sum()

    f = spmd_step.make_spmd_grad_fn(loss_fn, _spmd_mesh())
    return (lambda: f(params, x))


def _cnn_spmd_step(name: str, *, image_size: int, width: float,
                   batch: int = 2):
    from repro.models.cnn import build_cnn
    from repro.sharding import spmd_step
    model = build_cnn(name, image_size=image_size, width=width,
                      num_classes=10)
    params = model.init(jax.random.PRNGKey(0))
    images = jnp.zeros((batch, image_size, image_size, 3), jnp.float32)
    labels = jnp.zeros((batch,), jnp.int32)
    policy = _audit_policy()

    def loss_fn(p, b):
        return model.loss(p, b["images"], b["labels"], policy)

    f = spmd_step.make_spmd_grad_fn(loss_fn, _spmd_mesh())
    return (lambda: f(params, {"images": images, "labels": labels}))


WORKLOADS = {
    # VGG16: the deep sequential CNN (dense convs at every depth).
    "vgg16": lambda: _cnn_step("vgg16", image_size=16, width=0.125),
    # MobileNet: the depthwise/pointwise stack — exercises the grouped
    # engine with degenerate K = R·S tiles end to end.
    "mobilenet": lambda: _cnn_step("mobilenet", image_size=16, width=0.25),
    # ReLU-FFN: the linear-layer fused unit (act_matmul/matmul path).
    "ffn_relu": lambda: _ffn_step(),
    # SPMD variants: the same units inside a shard_map body with the
    # bitmap-compressed gradient all-reduce — the lifecycle contracts plus
    # COLLECTIVE_UNTAGGED, verified through the shard_map descent.
    "ffn_relu_spmd": lambda: _ffn_spmd_step(),
    "vgg16_spmd": lambda: _cnn_spmd_step("vgg16", image_size=16,
                                         width=0.125),
}


def audit_workloads(names=None) -> List[Violation]:
    """Trace-and-audit the standard workloads; [] is the contract on main."""
    out: List[Violation] = []
    for name in (names or sorted(WORKLOADS)):
        thunk = WORKLOADS[name]()
        out += audit_fn(thunk, workload=name)
    return out
