"""Interpret-mode shadow execution of the Pallas kernels, with tripwires.

``pl.pallas_call(..., interpret=True)`` checks VALUES; it cannot see a
write that lands twice, a store that strays outside the padded grid, or a
stale accumulator read — those all still produce *some* value.  This
module re-executes the kernel *functions* (the plain Python bodies in
``kernels/masked_matmul.py`` / ``kernels/queue_builder.py``) over numpy
shadow memory with every ref access instrumented:

  ACC_READ_BEFORE_WRITE  a VMEM accumulator is read (``+=`` reads!) in an
                         output tile's K-chain before that chain zeroed it
                         — silent reuse of the previous tile's partial sums.
  DOUBLE_WRITE           an output tile is written more than once (or never)
                         across the grid; the contract is exactly one
                         writeback per tile, at the last K step.
  STORE_OOB              a store outside the ref's padded block window
                         (numpy would silently wrap negative indices; the
                         shadow ref bounds-checks *before* storing).
  QUEUE_WRITE_OOB        the queue builder stores a slot index beyond the
                         dump slot (``> capacity``) — overflow corrupting
                         memory past the queue.
  DUMP_SLOT_LEAK         live queue slots not written exactly once, or dead
                         slots written at all (post-init) — compaction
                         leaking through the dump-slot quarantine.
  QUEUE_ORDER            the final queue content (or emitted live count)
                         disagrees with ``core.workredist.static_queue_order``.

The kernel bodies only reach ``pl`` / ``jnp`` / ``jax`` through module
globals, so a shadow run swaps those globals for shims for the duration of
the call — no kernel code changes, and the *same* function objects that the
real ``pallas_call`` launches are the ones audited.  Each driver hard-codes
its kernel family's grid / BlockSpec geometry: that geometry IS part of the
static contract being checked, not an input.  ``kernel_fn=`` overrides let
the self-tests plant mutant kernels and prove every tripwire fires
(tests/test_analysis.py).
"""
from __future__ import annotations

import importlib
import sys
from typing import Callable, List, Optional, Tuple

import numpy as np

from .report import Violation


# ---------------------------------------------------------------------------
# Shadow memory
# ---------------------------------------------------------------------------

class ShadowRef:
    """Backing store for one ref: data + per-element write counts.

    ``epochal`` refs model the VMEM accumulator: the driver bumps ``epoch``
    when a new output tile's K-chain begins, and a read while
    ``last_write_epoch < epoch`` is a stale read.  ``split_bulk`` refs (the
    queue outputs) count whole-window initializations separately from
    per-slot stores, so the dump-slot accounting can ignore the one
    sanctioned ``ref[...] = zeros`` init.
    """

    def __init__(self, shape, dtype, name: str, *,
                 epochal: bool = False, split_bulk: bool = False):
        self.data = np.zeros(shape, dtype)
        self.writes = np.zeros(shape, np.int64)
        self.bulk_writes = 0
        self.name = name
        self.epochal = epochal
        self.split_bulk = split_bulk
        self.epoch = 0
        self.last_write_epoch = -1


def input_ref(arr: np.ndarray, name: str) -> ShadowRef:
    """An input operand wrapped as shadow memory (kernels must not write
    inputs; if one did, its write counts would expose it)."""
    s = ShadowRef(arr.shape, arr.dtype, name)
    s.data = np.asarray(arr)
    return s


class RefView:
    """One grid step's window onto a ShadowRef (emulates the BlockSpec)."""

    def __init__(self, shadow: ShadowRef, window, san: "_Sanitizer"):
        self.shadow = shadow
        self.window = window          # tuple of slices into shadow.data
        self.san = san

    @property
    def dtype(self):
        return self.shadow.data.dtype

    @property
    def shape(self):
        return self.shadow.data[self.window].shape

    def _sel(self, idx):
        """Index normalized against the window; None if out of bounds."""
        view_shape = self.shape
        if idx is Ellipsis:
            idx = (slice(None),) * len(view_shape)
        if not isinstance(idx, tuple):
            idx = (idx,)
        idx = tuple(int(x) if isinstance(x, (np.integer, np.ndarray))
                    and np.ndim(x) == 0 else x for x in idx)
        for d, x in enumerate(idx):
            if isinstance(x, int):
                if not (0 <= x < view_shape[d]):
                    return None
            elif isinstance(x, slice):
                lo = 0 if x.start is None else int(x.start)
                hi = view_shape[d] if x.stop is None else int(x.stop)
                if lo < 0 or hi > view_shape[d]:
                    return None
        return idx

    def __getitem__(self, idx):
        if self.shadow.epochal \
                and self.shadow.last_write_epoch < self.shadow.epoch:
            self.san.report(
                "ACC_READ_BEFORE_WRITE",
                f"{self.shadow.name} read at {self.san.step_label()} before "
                f"this tile's K-chain initialized it")
        sel = self._sel(idx)
        if sel is None:
            return np.zeros((1,), self.dtype)  # OOB read: inert
        return np.array(self.shadow.data[self.window][sel])

    def __setitem__(self, idx, val):
        sel = self._sel(idx)
        if sel is None:
            self.san.report(
                "STORE_OOB",
                f"store to {self.shadow.name}{idx!r} outside its "
                f"{self.shape} block window at {self.san.step_label()}")
            return
        target = self.shadow.data[self.window]
        probe = np.zeros_like(target, dtype=bool)
        probe[sel] = True
        if self.shadow.split_bulk and probe.all():
            self.shadow.bulk_writes += 1
        else:
            counts = self.shadow.writes[self.window]
            counts[sel] += 1
            self.shadow.writes[self.window] = counts
        target[sel] = val
        self.shadow.data[self.window] = target
        self.shadow.last_write_epoch = self.shadow.epoch


# ---------------------------------------------------------------------------
# Module-global shims: pl / jnp / jax as seen from inside a kernel body
# ---------------------------------------------------------------------------

class _PlShim:
    def __init__(self, san: "_Sanitizer"):
        self._san = san

    def program_id(self, d):
        return self._san.grid_point[d]

    def num_programs(self, d):
        return self._san.grid_shape[d]

    @staticmethod
    def when(cond):
        def deco(fn):
            if bool(cond):
                fn()
            return fn
        return deco

    @staticmethod
    def dslice(start, size):
        return slice(int(start), int(start) + int(size))


class _JnpShim:
    """numpy plus the handful of jnp-isms the kernels use on refs."""

    int32 = np.int32
    float32 = np.float32
    bool_ = np.bool_

    @staticmethod
    def zeros_like(x):
        if isinstance(x, RefView):
            return np.zeros(x.shape, x.dtype)
        return np.zeros_like(x)

    @staticmethod
    def dot(a, b, preferred_element_type=np.float32):
        return np.dot(np.asarray(a, np.float32), np.asarray(b, np.float32)) \
            .astype(preferred_element_type)

    def __getattr__(self, name):
        return getattr(np, name)


class _LaxShim:
    @staticmethod
    def fori_loop(lo, hi, body, init):
        # Concrete Python loop: indices stay ints, so the shadow write log
        # sees real slot numbers (a traced fori_loop would hide them).
        carry = init
        for e in range(int(lo), int(hi)):
            carry = body(e, carry)
        return carry


class _JaxShim:
    def __init__(self):
        self.lax = _LaxShim()


class _Sanitizer:
    """Per-run state: grid position, violation log, and the global swap."""

    def __init__(self, kernel_fn: Callable, workload: str):
        self.kernel_fn = kernel_fn
        self.workload = workload
        self.grid_point: Tuple[int, ...] = ()
        self.grid_shape: Tuple[int, ...] = ()
        self.violations: List[Violation] = []
        self._seen = set()

    def step_label(self) -> str:
        return f"grid{tuple(self.grid_point)}"

    def report(self, code: str, message: str):
        key = (code, message)
        if key in self._seen:
            return
        self._seen.add(key)
        self.violations.append(Violation(
            "kernel", code, f"{self.kernel_fn.__name__}@{self.step_label()}",
            message, self.workload))

    def run(self, grid, step):
        """Iterate the grid in C order (K innermost, matching the TPU's
        sequential grid) calling ``step(point)`` with the kernel's module
        globals shimmed."""
        mod = sys.modules[self.kernel_fn.__module__]
        saved = {n: getattr(mod, n, None) for n in ("pl", "jnp", "jax")}
        mod.pl = _PlShim(self)
        mod.jnp = _JnpShim()
        mod.jax = _JaxShim()
        try:
            self.grid_shape = tuple(grid)
            for point in np.ndindex(*grid):
                self.grid_point = point
                step(point)
        finally:
            for n, v in saved.items():
                setattr(mod, n, v)


def _check_single_writeback(san: _Sanitizer, o: ShadowRef, tiles):
    """Every listed output tile window written exactly once, elementwise."""
    for label, window in tiles:
        w = o.writes[window]
        if (w > 1).any():
            san.report("DOUBLE_WRITE",
                       f"output tile {label} written {int(w.max())} times "
                       f"(contract: once, at the last K step)")
        elif (w == 0).any():
            san.report("DOUBLE_WRITE",
                       f"output tile {label} never written "
                       f"(contract: every tile written once)")


def _tile3(gi, i, j, bm, bn):
    return (slice(gi, gi + 1), slice(i * bm, (i + 1) * bm),
            slice(j * bn, (j + 1) * bn))


# ---------------------------------------------------------------------------
# Drivers — one per kernel family; geometry mirrored from the wrappers
# ---------------------------------------------------------------------------

def run_predicated_grouped(
    a: np.ndarray, b: np.ndarray,            # (G, M, K), (G, K, N)
    out_mask: np.ndarray, a_mask: np.ndarray, b_mask: np.ndarray,
    *, bm: int, bk: int, bn: int,
    epilogue_mult: Optional[np.ndarray] = None,   # (G, M, N)
    emit_gran: Optional[Tuple[int, int]] = None,  # bitmap_emit granularity
    kernel_fn: Optional[Callable] = None,
    workload: str = "",
):
    """Shadow-run the grouped predicated kernel over grid (G, Mb, Nb, Kb).

    With ``emit_gran`` the emitted-bitmap output gets its own shadow ref:
    its stores are bounds-checked and held to the same exactly-one-
    writeback-per-tile contract as the data output."""
    mmk = importlib.import_module("repro.kernels.masked_matmul")
    if kernel_fn is None:
        kernel_fn = mmk.gmm_kernel_variant(epilogue_mult is not None,
                                           emit_gran)
    g, m, k = a.shape
    n = b.shape[2]
    ni, nj, nk = m // bm, n // bn, k // bk

    san = _Sanitizer(kernel_fn, workload)
    o = ShadowRef((g, m, n), np.float32, "o_ref")
    acc = ShadowRef((bm, bn), np.float32, "acc_ref", epochal=True)
    a_s = input_ref(a, "a_ref")
    b_s = input_ref(b, "b_ref")
    mult_s = None if epilogue_mult is None \
        else input_ref(np.asarray(epilogue_mult, np.float32), "mult_ref")
    bits = None
    if emit_gran is not None:
        er, ec = emit_gran
        bits = ShadowRef((g, m // er, n // ec), np.int32, "bits_ref")
    om = np.asarray(out_mask, np.int32)
    am = np.asarray(a_mask, np.int32)
    bmsk = np.asarray(b_mask, np.int32)

    def step(point):
        gi, i, j, kk = point
        if kk == 0:
            acc.epoch += 1      # a new output tile's K-chain begins
        refs = [
            RefView(a_s, (slice(gi, gi + 1), slice(i * bm, (i + 1) * bm),
                          slice(kk * bk, (kk + 1) * bk)), san),
            RefView(b_s, (slice(gi, gi + 1), slice(kk * bk, (kk + 1) * bk),
                          slice(j * bn, (j + 1) * bn)), san),
        ]
        if mult_s is not None:
            refs.append(RefView(mult_s, _tile3(gi, i, j, bm, bn), san))
        refs.append(RefView(o, _tile3(gi, i, j, bm, bn), san))
        if bits is not None:
            er, ec = emit_gran
            refs.append(RefView(
                bits, _tile3(gi, i, j, bm // er, bn // ec), san))
        refs.append(RefView(acc, (slice(None), slice(None)), san))
        kernel_fn(om, am, bmsk, *refs)

    san.run((g, ni, nj, nk), step)
    tiles = [(f"(g={gi},i={i},j={j})", _tile3(gi, i, j, bm, bn))
             for gi in range(g) for i in range(ni) for j in range(nj)]
    _check_single_writeback(san, o, tiles)
    if bits is not None:
        er, ec = emit_gran
        btiles = [(f"bits(g={gi},i={i},j={j})",
                   _tile3(gi, i, j, bm // er, bn // ec))
                  for gi in range(g) for i in range(ni) for j in range(nj)]
        _check_single_writeback(san, bits, btiles)
    return san.violations, o.data


def run_compact_grouped(
    a: np.ndarray, b: np.ndarray,            # (G, M, K), (G, K, N)
    gg: np.ndarray, ii: np.ndarray, jj: np.ndarray,   # (S,) queue coords
    n_active: np.ndarray,                    # (1,)
    a_mask: np.ndarray, b_mask: np.ndarray,
    *, bm: int, bk: int, bn: int,
    epilogue_mult: Optional[np.ndarray] = None,   # (G, M, N)
    emit_gran: Optional[Tuple[int, int]] = None,  # bitmap_emit granularity
    kernel_fn: Optional[Callable] = None,
    workload: str = "",
):
    """Shadow-run the grouped compacted kernel over grid (S, Kb)."""
    mmk = importlib.import_module("repro.kernels.masked_matmul")
    if kernel_fn is None:
        kernel_fn = mmk.gmm_compact_kernel_variant(epilogue_mult is not None,
                                                   emit_gran)
    k = a.shape[2]
    nk = k // bk
    gg = np.asarray(gg, np.int32)
    ii = np.asarray(ii, np.int32)
    jj = np.asarray(jj, np.int32)
    (s_cap,) = ii.shape

    san = _Sanitizer(kernel_fn, workload)
    o = ShadowRef((s_cap, bm, bn), np.float32, "o_ref")
    acc = ShadowRef((1, bm, bn), np.float32, "acc_ref", epochal=True)
    a_s = input_ref(a, "a_ref")
    b_s = input_ref(b, "b_ref")
    mult_s = None if epilogue_mult is None \
        else input_ref(np.asarray(epilogue_mult, np.float32), "mult_ref")
    bits = None
    if emit_gran is not None:
        er, ec = emit_gran
        bits = ShadowRef((s_cap, bm // er, bn // ec), np.int32, "bits_ref")
    na = np.asarray(n_active, np.int32)
    am = np.asarray(a_mask, np.int32)
    bmsk = np.asarray(b_mask, np.int32)

    def step(point):
        s, kk = point
        if kk == 0:
            acc.epoch += 1
        gi, i, j = int(gg[s]), int(ii[s]), int(jj[s])
        refs = [
            RefView(a_s, (slice(gi, gi + 1), slice(i * bm, (i + 1) * bm),
                          slice(kk * bk, (kk + 1) * bk)), san),
            RefView(b_s, (slice(gi, gi + 1), slice(kk * bk, (kk + 1) * bk),
                          slice(j * bn, (j + 1) * bn)), san),
        ]
        if mult_s is not None:
            refs.append(RefView(mult_s, _tile3(gi, i, j, bm, bn), san))
        refs.append(RefView(
            o, (slice(s, s + 1), slice(None), slice(None)), san))
        if bits is not None:
            refs.append(RefView(
                bits, (slice(s, s + 1), slice(None), slice(None)), san))
        refs.append(RefView(acc, (slice(None),) * 3, san))
        kernel_fn(gg, ii, jj, na, am, bmsk, *refs)

    san.run((s_cap, nk), step)
    tiles = [(f"(s={s})", (slice(s, s + 1), slice(None), slice(None)))
             for s in range(s_cap)]
    _check_single_writeback(san, o, tiles)
    if bits is not None:
        btiles = [(f"bits(s={s})", (slice(s, s + 1), slice(None),
                                    slice(None)))
                  for s in range(s_cap)]
        _check_single_writeback(san, bits, btiles)
    return san.violations, o.data


def run_queue_builder(
    bitmap: np.ndarray,                      # (Mb, Nb)
    *, capacity: int, launch_block: int = 8,
    kernel_fn: Optional[Callable] = None,
    workload: str = "",
):
    """Shadow-run the prefix-sum queue builder over grid (T // lb,)."""
    from repro.core.workredist import static_queue_order
    qbk = importlib.import_module("repro.kernels.queue_builder")
    kernel_fn = kernel_fn or qbk._queue_builder_kernel
    mb, nb = np.asarray(bitmap).shape
    t = mb * nb
    lb = min(launch_block, t)
    tp = (t + lb - 1) // lb * lb
    flat = np.asarray(bitmap, np.int32).reshape(-1)
    if tp != t:
        flat = np.pad(flat, (0, tp - t))
    blocks_s = input_ref(flat.reshape(tp // lb, lb), "bm_ref")

    san = _Sanitizer(kernel_fn, workload)
    ii = ShadowRef((capacity + 1, 1), np.int32, "ii_ref", split_bulk=True)
    jj = ShadowRef((capacity + 1, 1), np.int32, "jj_ref", split_bulk=True)
    cnt = ShadowRef((1, 1), np.int32, "cnt_ref")
    carry = ShadowRef((1,), np.int32, "carry_ref")

    def full(s):
        return tuple(slice(None) for _ in s.data.shape)

    def step(point):
        (b,) = point
        kernel_fn(RefView(blocks_s, (slice(b, b + 1), slice(None)), san),
                  RefView(ii, full(ii), san), RefView(jj, full(jj), san),
                  RefView(cnt, full(cnt), san),
                  RefView(carry, full(carry), san),
                  cap=capacity, nj=nb, lb=lb)

    san.run((tp // lb,), step)

    # Name the queue-specific failure: a store past the dump slot.
    for i, v in enumerate(list(san.violations)):
        if v.code == "STORE_OOB" and ("ii_ref" in v.message
                                      or "jj_ref" in v.message):
            san.violations[i] = Violation(
                "kernel", "QUEUE_WRITE_OOB", v.where,
                v.message + " — queue slot beyond the dump slot", v.workload)

    ref_ii, ref_jj, n_live = static_queue_order(np.asarray(bitmap), capacity)
    live = min(int(n_live), capacity)

    # Dump-slot quarantine: live slots stored exactly once (the b==0
    # whole-window init is a bulk write, counted separately), dead slots
    # untouched; everything else must have landed in the dump row.
    for name, ref in (("ii", ii), ("jj", jj)):
        w = ref.writes[:capacity, 0]
        if (w[:live] != 1).any():
            bad = int(np.flatnonzero(w[:live] != 1)[0])
            san.report("DUMP_SLOT_LEAK",
                       f"live {name} slot {bad} stored {int(w[bad])} times "
                       f"(contract: exactly once)")
        if live < capacity and (w[live:] != 0).any():
            bad = live + int(np.flatnonzero(w[live:] != 0)[0])
            san.report("DUMP_SLOT_LEAK",
                       f"dead {name} slot {bad} stored post-init "
                       f"(dead/overflow stores belong in the dump slot)")

    got_ii, got_jj = ii.data[:capacity, 0], jj.data[:capacity, 0]
    if not (np.array_equal(got_ii, ref_ii)
            and np.array_equal(got_jj, ref_jj)):
        san.report("QUEUE_ORDER",
                   "final queue content differs from the WDU reference "
                   "order (core.workredist.static_queue_order)")
    if int(cnt.data[0, 0]) != int(n_live):
        san.report("QUEUE_ORDER",
                   f"emitted n_live={int(cnt.data[0, 0])} != true set-bit "
                   f"count {int(n_live)}")
    return san.violations, (got_ii, got_jj, int(cnt.data[0, 0]))


# ---------------------------------------------------------------------------
# Standard sweep — the kernel half of the zero-violation gate
# ---------------------------------------------------------------------------

def sanitize_all() -> List[Violation]:
    """Shadow-run every launched kernel family on representative sparse
    geometries (half-dead masks, empty, full, and overflowing queues)."""
    from repro.core.workredist import static_queue_order
    out: List[Violation] = []
    r = np.random.RandomState(0)

    g, m, k, n, bsz = 2, 8, 8, 8, 4
    a = r.randn(g, m, k).astype(np.float32)
    b = r.randn(g, k, n).astype(np.float32)
    om = (r.rand(g, m // bsz, n // bsz) > 0.5).astype(np.int32)
    am = (r.rand(g, m // bsz, k // bsz) > 0.3).astype(np.int32)
    bmm = (r.rand(g, k // bsz, n // bsz) > 0.3).astype(np.int32)
    mult = r.rand(g, m, n).astype(np.float32)

    vs, _ = run_predicated_grouped(a, b, om, am, bmm, bm=bsz, bk=bsz, bn=bsz,
                                   workload="predicated:g2")
    out += vs
    vs, _ = run_predicated_grouped(a, b, om, am, bmm, bm=bsz, bk=bsz, bn=bsz,
                                   epilogue_mult=mult,
                                   workload="predicated:epilogue")
    out += vs
    # bitmap_emit writeback stage: alone, and composed with sigma_prime.
    vs, _ = run_predicated_grouped(a, b, om, am, bmm, bm=bsz, bk=bsz, bn=bsz,
                                   emit_gran=(2, 2),
                                   workload="predicated:emit")
    out += vs
    vs, _ = run_predicated_grouped(a, b, om, am, bmm, bm=bsz, bk=bsz, bn=bsz,
                                   epilogue_mult=mult, emit_gran=(2, 2),
                                   workload="predicated:epilogue+emit")
    out += vs

    # Compacted schedule over the real queue of the same out-mask.
    ni = m // bsz
    flat_om = om.reshape(g * ni, n // bsz)
    fii, fjj, n_live = static_queue_order(flat_om, flat_om.size)
    gg = (fii // ni).astype(np.int32)
    ii = (fii % ni).astype(np.int32)
    na = np.array([n_live], np.int32)
    vs, _ = run_compact_grouped(a, b, gg, ii, fjj, na, am, bmm,
                                bm=bsz, bk=bsz, bn=bsz,
                                workload="compact:g2")
    out += vs
    vs, _ = run_compact_grouped(a, b, gg, ii, fjj, na, am, bmm,
                                bm=bsz, bk=bsz, bn=bsz, epilogue_mult=mult,
                                workload="compact:epilogue")
    out += vs
    vs, _ = run_compact_grouped(a, b, gg, ii, fjj, na, am, bmm,
                                bm=bsz, bk=bsz, bn=bsz, emit_gran=(2, 2),
                                workload="compact:emit")
    out += vs
    vs, _ = run_compact_grouped(a, b, gg, ii, fjj, na, am, bmm,
                                bm=bsz, bk=bsz, bn=bsz, epilogue_mult=mult,
                                emit_gran=(2, 2),
                                workload="compact:epilogue+emit")
    out += vs

    for label, bmp, cap in [
        ("queue:half", (r.rand(4, 6) > 0.5).astype(np.int32), 24),
        ("queue:empty", np.zeros((3, 5), np.int32), 15),
        ("queue:full", np.ones((4, 4), np.int32), 16),
        ("queue:overflow", np.ones((4, 4), np.int32), 5),
        ("queue:ragged", (np.arange(7 * 3).reshape(7, 3) % 2)
         .astype(np.int32), 11),
    ]:
        vs, _ = run_queue_builder(bmp, capacity=cap, launch_block=4,
                                  workload=label)
        out += vs
    return out
