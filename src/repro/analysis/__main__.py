"""CLI entry point: ``python -m repro.analysis [--fail-on-violation] ...``.

Runs the full verifier — jaxpr lifecycle audit over the standard workloads,
the kernel sanitizer sweep, and the repo lint — and prints one combined
violation table.  ``--json`` / ``--csv`` write the same rows as artifacts
(what CI uploads); ``--fail-on-violation`` makes any row exit 1.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from . import jaxpr_audit, kernel_sanitizer, lint, report

# Directories lint sweeps by default: everything that CALLS the kernels.
# tests/ is excluded — the frozen-reference suites pin the deprecated shims
# against sparse_gemm on purpose (docs/gemm_api.md).
LINT_ROOTS = ("src", "benchmarks", "examples")


def _repo_root() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static bitmap-contract verifier (jaxpr + kernel + lint)")
    ap.add_argument("--fail-on-violation", action="store_true",
                    help="exit 1 if any checker reports a violation")
    ap.add_argument("--workloads", nargs="*", default=None,
                    metavar="NAME",
                    help=f"jaxpr workloads (default: all of "
                         f"{sorted(jaxpr_audit.WORKLOADS)})")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["jaxpr", "kernel", "lint"],
                    help="checkers to skip")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the violation rows as JSON")
    ap.add_argument("--csv", metavar="PATH", default=None,
                    help="write the violation rows as CSV")
    args = ap.parse_args(argv)

    violations = []
    for name, run in [
        ("jaxpr", lambda: jaxpr_audit.audit_workloads(args.workloads)),
        ("kernel", kernel_sanitizer.sanitize_all),
        ("lint", lambda: lint.lint_paths(
            [p for r in LINT_ROOTS
             if os.path.isdir(p := os.path.join(_repo_root(), r))])),
    ]:
        if name in args.skip:
            print(f"[analysis] {name}: skipped")
            continue
        t0 = time.time()
        vs = run()
        violations += vs
        print(f"[analysis] {name}: {len(vs)} violation(s) "
              f"({time.time() - t0:.1f}s)")

    print()
    print(report.format_table(violations, title="contract violations"))
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            f.write(report.to_json(violations))
    if args.csv:
        with open(args.csv, "w", encoding="utf-8") as f:
            f.write(report.to_csv(violations))
    if violations and args.fail_on_violation:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
