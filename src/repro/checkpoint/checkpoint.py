"""Crash-safe checkpointing: fsync'd atomic commits, keep-last-k,
auto-resume with corrupt-newest fallback, elastic mesh-reshape on restore.

Layout:  <dir>/step_00001230/            (atomic: written as .tmp, renamed)
             leaves.npz                  (flat leaf arrays, path-keyed)
             treedef.json                (leaf paths + metadata)
             state.json                  (optional host-side extras:
                                          autotune cache + guard state)

Arrays are saved as *full logical values* (host-gathered), so a restore
may target a different mesh/device-count than the writer — the launcher
simply device_puts with the new sharding (``restore_resharded``).  That is
the elastic-restart path: kill a 512-chip job, restart on 256 chips, keep
training.

Commit protocol (docs/resilience.md):

  1. payload files are written into ``step_X.tmp`` and fsync'd,
  2. the tmp dir itself is fsync'd (entries durable before the rename),
  3. ``os.rename(tmp, final)`` is the commit point; a previous ``final``
     for the SAME step is moved aside FIRST and deleted only AFTER the
     new rename lands — the previous intact checkpoint is never destroyed
     while the new one is still uncommitted,
  4. the parent dir is fsync'd, then older steps are pruned.

Failures raise typed ``CheckpointError``s (never bare ``assert``s, which
vanish under ``python -O``).  ``restore`` with no explicit step falls back
to the previous intact checkpoint when the newest is corrupt or partial
(counted as ``guard:ckpt_fallback``; the wreck is quarantined to
``*.corrupt`` and cleared by the next ``_prune``).  Partially-written
checkpoints are never visible (rename is the commit point) and are
garbage-collected on the next save.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import stats

_STEP_RE = re.compile(r"^step_(\d{8})$")
# Suffixes of non-committed / quarantined dirs _prune clears.
_WRECKAGE_SUFFIXES = (".tmp", ".old", ".corrupt")


class CheckpointError(Exception):
    """Typed checkpoint failure carrying step/leaf context.

    ``step`` is the checkpoint step involved (None when unknown); ``leaf``
    the offending leaf path/key for payload mismatches."""

    def __init__(self, msg: str, *, step: Optional[int] = None,
                 leaf: Optional[str] = None):
        ctx = []
        if step is not None:
            ctx.append(f"step={step}")
        if leaf is not None:
            ctx.append(f"leaf={leaf}")
        super().__init__(f"{msg}" + (f" [{', '.join(ctx)}]" if ctx else ""))
        self.step = step
        self.leaf = leaf


class CheckpointCorruptError(CheckpointError):
    """The stored payload is unreadable or inconsistent (truncated npz,
    missing keys, leaf count/shape drift) — restore may fall back to an
    older intact checkpoint."""


# Fault-injection crash points (repro/runtime/faults.py): an installed hook
# may raise at a named protocol point to simulate a writer dying there.
# None (the default) is a zero-cost passthrough.
_CRASH_HOOK = None


def set_crash_hook(fn):
    """Install (or, with None, remove) the crash-point hook; returns the
    previous hook."""
    global _CRASH_HOOK
    prev, _CRASH_HOOK = _CRASH_HOOK, fn
    return prev


def _crash_point(name: str) -> None:
    if _CRASH_HOOK is not None:
        _CRASH_HOOK(name)


def _step_dir(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:08d}")


def _fsync_file(p: str) -> None:
    fd = os.open(p, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(p: str) -> None:
    fd = os.open(p, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> Tuple[dict, list]:
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat = {}
    paths = []
    for i, (kp, leaf) in enumerate(leaves_with_path):
        key = f"leaf_{i:05d}"
        flat[key] = np.asarray(jax.device_get(leaf))
        paths.append(jax.tree_util.keystr(kp))
    return flat, paths


def save(path: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    """Durable atomic checkpoint write; prunes to the newest ``keep``
    checkpoints.  ``extra`` (JSON-able) is persisted as ``state.json`` —
    the host-side resume payload (autotune cache, guard state) that keeps
    a restart from cold-starting its schedules."""
    os.makedirs(path, exist_ok=True)
    final = _step_dir(path, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, paths = _flatten(tree)
    np.savez(os.path.join(tmp, "leaves.npz"), **flat)
    _fsync_file(os.path.join(tmp, "leaves.npz"))
    _crash_point("checkpoint:post_leaves")
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(tmp, "treedef.json"), "w") as f:
        json.dump({"paths": paths, "n_leaves": len(paths),
                   "treedef": str(treedef), "step": step}, f)
        f.flush()
        os.fsync(f.fileno())
    if extra is not None:
        with open(os.path.join(tmp, "state.json"), "w") as f:
            json.dump(extra, f)
            f.flush()
            os.fsync(f.fileno())
    _fsync_dir(tmp)
    _crash_point("checkpoint:pre_commit")
    if os.path.exists(final):
        # Same-step rewrite: the intact previous dir must survive until
        # the new one is committed — move it aside, never rmtree first.
        old = final + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(final, old)
        os.rename(tmp, final)                   # commit point
        shutil.rmtree(old, ignore_errors=True)
    else:
        os.rename(tmp, final)                   # commit point
    _fsync_dir(path)
    _prune(path, keep)
    return final


def _prune(path: str, keep: int) -> None:
    steps = _list_steps(path)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(path, s), ignore_errors=True)
    # clean wreckage: crashed writers (.tmp), interrupted same-step
    # rewrites (.old), quarantined corrupt restores (.corrupt)
    for name in os.listdir(path):
        if name.endswith(_WRECKAGE_SUFFIXES):
            shutil.rmtree(os.path.join(path, name), ignore_errors=True)


def _list_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(path, name, "treedef.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(path: str) -> Optional[int]:
    steps = _list_steps(path)
    return steps[-1] if steps else None


def _load_step(path: str, step: int, template: Any) -> Any:
    """Load one committed checkpoint into ``template``'s structure.

    Raises ``CheckpointCorruptError`` for unreadable/inconsistent payloads
    (the fallback-able class) — typed, with step/leaf context."""
    d = _step_dir(path, step)
    try:
        with np.load(os.path.join(d, "leaves.npz")) as z:
            flat = {k: z[k] for k in z.files}
    except Exception as e:
        # np.load failures (truncated zip, bad magic, missing file) are
        # exactly the corrupt-newest class — they must not propagate past
        # the latest_step retry in restore().
        raise CheckpointCorruptError(
            f"unreadable leaves.npz under {d}: {e!r}", step=step) from e
    t_leaves, tdef = jax.tree_util.tree_flatten(template)
    if len(flat) != len(t_leaves):
        raise CheckpointCorruptError(
            f"leaf count mismatch: checkpoint has {len(flat)}, template "
            f"wants {len(t_leaves)}", step=step)
    out = []
    for i, want in enumerate(t_leaves):
        key = f"leaf_{i:05d}"
        if key not in flat:
            raise CheckpointCorruptError(
                f"missing array {key!r} in leaves.npz", step=step, leaf=key)
        got = flat[key]
        if got.shape != tuple(want.shape):
            raise CheckpointCorruptError(
                f"shape mismatch: checkpoint {got.shape} vs template "
                f"{tuple(want.shape)}", step=step, leaf=key)
        out.append(jnp.asarray(got, dtype=want.dtype))
    return jax.tree_util.tree_unflatten(tdef, out)


def _quarantine(path: str, step: int) -> None:
    """Move a corrupt committed step dir aside (``*.corrupt``) so the next
    ``_list_steps`` no longer offers it and the next ``_prune`` clears it."""
    d = _step_dir(path, step)
    try:
        target = d + ".corrupt"
        if os.path.exists(target):
            shutil.rmtree(target)
        os.rename(d, target)
    except OSError:
        pass                         # best-effort: fallback still proceeds


def restore(path: str, template: Any, step: Optional[int] = None,
            *, fallback: bool = True) -> Tuple[int, Any]:
    """Restore into the structure of ``template`` (shapes validated).

    With ``step=None`` (auto-resume), a corrupt/partial newest checkpoint
    does NOT kill the restore: it is quarantined, ``guard:ckpt_fallback``
    is counted, and the previous intact checkpoint is loaded instead
    (``fallback=False`` disables the retry).  An explicit ``step`` is
    always loaded exactly, corrupt-or-not raising on failure."""
    if step is not None:
        return step, _load_step(path, step, template)
    steps = _list_steps(path)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {path}")
    last_err: Optional[CheckpointError] = None
    for s in reversed(steps):
        try:
            return s, _load_step(path, s, template)
        except CheckpointCorruptError as e:
            if not fallback:
                raise
            stats.record("guard:ckpt_fallback")
            _quarantine(path, s)
            last_err = e
    raise CheckpointError(
        f"every checkpoint under {path} is corrupt "
        f"(newest failure: {last_err})", step=steps[-1])


def load_state(path: str, step: Optional[int] = None) -> Optional[dict]:
    """The ``state.json`` extra payload of a checkpoint (newest by
    default), or None when absent/unreadable — host-side resume state is
    best-effort and must never block a params restore."""
    if step is None:
        step = latest_step(path)
        if step is None:
            return None
    p = os.path.join(_step_dir(path, step), "state.json")
    try:
        with open(p) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def restore_resharded(path: str, template: Any, shardings: Any,
                      step: Optional[int] = None) -> Tuple[int, Any]:
    """Elastic restore: place each leaf with the given (new-mesh) sharding.

    ``shardings`` is a pytree of jax.sharding.Sharding matching template."""
    step, tree = restore(path, template, step)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
    return step, placed
