"""Fault-tolerant checkpointing: atomic writes, keep-last-k, auto-resume,
elastic mesh-reshape on restore.

Layout:  <dir>/step_00001230/            (atomic: written as .tmp, renamed)
             leaves.npz                  (flat leaf arrays, path-keyed)
             treedef.json                (leaf paths + metadata)

Arrays are saved as *full logical values* (host-gathered), so a restore
may target a different mesh/device-count than the writer — the launcher
simply device_puts with the new sharding (``restore_resharded``).  That is
the elastic-restart path: kill a 512-chip job, restart on 256 chips, keep
training.  Partially-written checkpoints are never visible (rename is the
commit point) and are garbage-collected on the next save.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _step_dir(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:08d}")


def _flatten(tree) -> Tuple[dict, list]:
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat = {}
    paths = []
    for i, (kp, leaf) in enumerate(leaves_with_path):
        key = f"leaf_{i:05d}"
        flat[key] = np.asarray(jax.device_get(leaf))
        paths.append(jax.tree_util.keystr(kp))
    return flat, paths


def save(path: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the newest ``keep`` checkpoints."""
    os.makedirs(path, exist_ok=True)
    final = _step_dir(path, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, paths = _flatten(tree)
    np.savez(os.path.join(tmp, "leaves.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(tmp, "treedef.json"), "w") as f:
        json.dump({"paths": paths, "n_leaves": len(paths),
                   "treedef": str(treedef), "step": step}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # commit point
    _prune(path, keep)
    return final


def _prune(path: str, keep: int) -> None:
    steps = _list_steps(path)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(path, s), ignore_errors=True)
    # clean stragglers from crashed writers
    for name in os.listdir(path):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(path, name), ignore_errors=True)


def _list_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(path, name, "treedef.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(path: str) -> Optional[int]:
    steps = _list_steps(path)
    return steps[-1] if steps else None


def restore(path: str, template: Any, step: Optional[int] = None
            ) -> Tuple[int, Any]:
    """Restore into the structure of ``template`` (shapes validated)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = _step_dir(path, step)
    with np.load(os.path.join(d, "leaves.npz")) as z:
        flat = {k: z[k] for k in z.files}
    leaves = [flat[f"leaf_{i:05d}"] for i in range(len(flat))]
    t_leaves, tdef = jax.tree_util.tree_flatten(template)
    assert len(leaves) == len(t_leaves), (len(leaves), len(t_leaves))
    out = []
    for got, want in zip(leaves, t_leaves):
        assert got.shape == tuple(want.shape), (got.shape, want.shape)
        out.append(jnp.asarray(got, dtype=want.dtype))
    return step, jax.tree_util.tree_unflatten(tdef, out)


def restore_resharded(path: str, template: Any, shardings: Any,
                      step: Optional[int] = None) -> Tuple[int, Any]:
    """Elastic restore: place each leaf with the given (new-mesh) sharding.

    ``shardings`` is a pytree of jax.sharding.Sharding matching template."""
    step, tree = restore(path, template, step)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
    return step, placed
