from .checkpoint import (CheckpointCorruptError, CheckpointError,  # noqa: F401
                         latest_step, load_state, restore,
                         restore_resharded, save, set_crash_hook)
