"""Quickstart: the paper's gradient-output-sparsity technique in 60 lines.

Builds a 3-layer ReLU MLP two ways — dense autodiff vs the fused
sparse-backprop units (output+input block skipping, work-redistribution
schedule) — and shows (1) gradients are EXACTLY equal (the technique is
lossless), (2) how much compute the block bitmaps let the backward skip.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import IN_OUT_WR, relu_matmul
from repro.core.sparsity import block_sparsity, relu_mask
from repro.kernels import ref


def main() -> None:
    policy = IN_OUT_WR.with_(kernel_impl="pallas", block=(16, 16, 16))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    ws = [jnp.asarray(rng.standard_normal(s) / s[0] ** 0.5, jnp.float32)
          for s in [(64, 128), (128, 128), (128, 32)]]
    # Trained ReLU nets develop dead units (the paper's WC sparsity,
    # Fig. 7c); emulate that structure via a bias so the block bitmaps
    # have teeth — benchmarks/kernel_audit.py quantifies capture vs
    # structure on real traces.
    bias = jnp.zeros((128,)).at[64:].set(-6.0)

    def net_sparse(ws):
        h = x @ ws[0] + bias                # first layer: raw input
        h2 = relu_matmul(h, ws[1], policy)  # fused ReLU→GEMM, sparse bwd
        h3 = relu_matmul(h2, ws[2], policy)
        return (h3 ** 2).mean()

    def net_dense(ws):
        h = x @ ws[0] + bias
        h = jnp.maximum(h, 0) @ ws[1]
        h = jnp.maximum(h, 0) @ ws[2]
        return (h ** 2).mean()

    g_sparse = jax.grad(net_sparse)(ws)
    g_dense = jax.grad(net_dense)(ws)
    max_err = max(float(jnp.abs(a - b).max())
                  for a, b in zip(g_sparse, g_dense))
    print(f"max |grad_sparse - grad_dense| = {max_err:.2e}  (lossless)")

    # what the backward pass skipped: block bitmap of the ReLU footprint
    h1 = x @ ws[0] + bias
    mask = relu_mask(h1)
    bs = float(block_sparsity(mask, 16, 16))
    es = float(jnp.mean(mask == 0))
    print(f"layer-1 activation sparsity: element={es:.1%}, "
          f"16x16-block={bs:.1%}")
    print("→ the dX GEMM for layer 2 skipped "
          f"{bs:.1%} of its output tiles (exact zeros by §3.2)")


if __name__ == "__main__":
    main()
