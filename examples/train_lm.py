"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
deterministic synthetic token stream, with checkpoint/restart fault
tolerance and the straggler detector live.

The config is a 12L/768d/12H GQA transformer (~90M params incl. tied
embeddings).  Optionally (--sparse-ffn) the FFN uses squared-ReLU routed
through the paper's sparse-backprod units — the beyond-paper transformer
application of the technique (loss curve is unchanged: the op is exact).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
Resume after a kill:  same command — it restarts from the last checkpoint.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ModelConfig, TrainConfig
from repro.launch.train import train_loop

LM100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=16384,
    ffn_activation="silu_glu",
    tie_embeddings=True,
    dtype="float32",
    q_chunk=128,
    kv_chunk=128,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    ap.add_argument("--sparse-ffn", action="store_true",
                    help="squared-ReLU FFN through the sparse-bwd units")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = LM100M
    if args.sparse_ffn:
        cfg = cfg.with_(ffn_activation="relu2", sparse_ffn_scenario="IN_OUT")
    tcfg = TrainConfig(
        learning_rate=args.lr, warmup_steps=20, total_steps=args.steps,
        microbatches=args.microbatches, checkpoint_every=50,
        keep_checkpoints=2)

    import jax
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: __import__("repro.models.transformer",
                                          fromlist=["lm_init"]).lm_init(
            jax.random.key(0), cfg))))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"ffn={cfg.ffn_activation}")
    out = train_loop(cfg, tcfg, batch_size=args.batch, seq_len=args.seq,
                     steps=args.steps, ckpt_dir=args.ckpt_dir, resume=True)
    print(f"first-10 mean loss {sum(out['losses'][:10])/10:.4f} → "
          f"last-10 mean {sum(out['losses'][-10:])/10:.4f}  "
          f"(resumed_from={out['resumed_from']}, "
          f"stragglers={len(out['straggler'].flags)})")


if __name__ == "__main__":
    main()
