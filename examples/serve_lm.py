"""Serve a small LM with batched requests through the continuous-batching
engine (more requests than batch slots; slots refill as requests finish).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import SMOKE_ARCHS
from repro.models.transformer import lm_init
from repro.serving.engine import GenRequest, ServeEngine


def main() -> None:
    cfg = SMOKE_ARCHS["smollm-360m"]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    engine = ServeEngine(cfg, params, batch_slots=4, max_len=128)

    prompts = [[1 + i, 7, 3, 11] for i in range(10)]
    t0 = time.time()
    for rid, p in enumerate(prompts):
        engine.submit(GenRequest(rid, p, max_tokens=16))
    done = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    for rid in sorted(done):
        print(f"req {rid}: {done[rid][:8]}…")
    print(f"\nserved {len(done)} requests / {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s, "
          f"{engine.index} engine ticks, 4 slots)")


if __name__ == "__main__":
    main()
