"""Train the paper's five CNNs with sparse backprop and report the
trace-driven accelerator cost model per scenario (the paper's Fig. 15
experiment, end to end: real training → real traces → cycle model).

Run:  PYTHONPATH=src python examples/cnn_training.py [--net vgg16] [--steps 5]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from repro.core.policy import IN_OUT_WR
from repro.data.pipeline import image_batch
from repro.models.cnn import NETWORKS, build_cnn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="vgg16", choices=list(NETWORKS))
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--kernel-impl", default="xla_ref",
                    choices=["xla_ref", "pallas"])
    ap.add_argument("--queue-builder", default="prefix_sum",
                    choices=["prefix_sum", "argsort"],
                    help="compact-queue construction on the pallas impl: "
                         "on-device prefix-sum compaction (default) or the "
                         "argsort reference")
    args = ap.parse_args()

    model = build_cnn(args.net, image_size=args.image_size, width=args.width,
                      num_classes=100)
    params = model.init(jax.random.key(0))
    policy = IN_OUT_WR.with_(kernel_impl=args.kernel_impl,
                             queue_builder=args.queue_builder)

    @jax.jit
    def step(params, img, labels):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, img, labels, policy))(params)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
        return params, loss

    print(f"training {args.net} with IN+OUT+WR sparse backprop…")
    for i in range(args.steps):
        img, labels = image_batch(0, i, batch=8, image_size=args.image_size,
                                  num_classes=100)
        params, loss = step(params, img, labels)
        print(f"  step {i}: loss {float(loss):.4f}")

    print("\naccelerator cost model (full ImageNet geometry, batch 16):")
    from benchmarks.common import network_totals
    totals = network_totals(args.net)
    dc = totals["DC"]["total_cycles"]
    for sc in ("DC", "IN", "IN_OUT", "IN_OUT_WR"):
        t = totals[sc]
        print(f"  {sc:10s}  {t['iteration_ms']:9.2f} ms/iter   "
              f"speedup {dc / t['total_cycles']:.2f}x   "
              f"energy {t['total_energy_j']:.2f} J")


if __name__ == "__main__":
    main()
