"""HLO collective parser + roofline arithmetic tests."""
import numpy as np

from repro.launch import hlo_analysis as ha

SAMPLE_HLO = """
  %ag.1 = bf16[256,1024]{1,0} all-gather(%p0), replica_groups={...}
  %ar.2 = f32[512]{0} all-reduce(%x), to_apply=%add
  %rs.3 = (f32[128,64]{1,0}, f32[128,64]{1,0}) reduce-scatter(%a, %b)
  %cp.4 = bf16[32,32]{1,0} collective-permute(%y)
  %a2a.5 = f32[16,16]{1,0} all-to-all(%z)
  %dot.6 = f32[1024,1024]{1,0} dot(%l, %r)
"""


def test_collective_bytes_parser():
    out = ha.collective_bytes(SAMPLE_HLO)
    assert out["all-gather"] == 256 * 1024 * 2
    assert out["all-reduce"] == 512 * 4
    assert out["reduce-scatter"] == 2 * 128 * 64 * 4
    assert out["collective-permute"] == 32 * 32 * 2
    assert out["all-to-all"] == 16 * 16 * 4
    assert out["count"] == 5
    assert out["total"] == sum(out[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_roofline_terms_and_fractions():
    rl = ha.roofline_terms(hlo_flops=197e12, hlo_bytes=819e9,
                           coll_bytes=25e9, model_flops=197e12 * 256 * 0.5)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 1.0) < 1e-9
    assert abs(rl.collective_s - 0.5) < 1e-9
    assert rl.dominant in ("compute", "memory")
    assert abs(rl.useful_flop_fraction(256) - 0.5) < 1e-9
    assert abs(rl.roofline_fraction(256) - 0.5) < 1e-9


def test_model_flops_conventions():
    from repro.configs import ARCHS
    from repro.configs.base import TRAIN_4K, DECODE_32K, PREFILL_32K
    n = 1e9
    cfg = ARCHS["smollm-360m"]
    assert ha.model_flops(cfg, TRAIN_4K, n) == 6 * n * 4096 * 256
    assert ha.model_flops(cfg, PREFILL_32K, n) == 2 * n * 32768 * 32
    assert ha.model_flops(cfg, DECODE_32K, n) == 2 * n * 128


def test_active_param_count_scales_moe():
    import jax
    from repro.configs import ARCHS
    from repro.models.transformer import lm_init
    cfg = ARCHS["grok-1-314b"]
    shapes = jax.eval_shape(lambda: lm_init(jax.random.key(0), cfg))
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
    active = ha.active_param_count(shapes, cfg)
    # grok: 8 experts top-2 → expert params scale 4×; experts dominate
    assert active < 0.45 * total
    assert active > 0.15 * total
