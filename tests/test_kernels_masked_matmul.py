"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs ref.py.

All cells drive the spec-driven ``sparse_gemm`` entry point (2-D requests
are the G=1 lowering of the grouped engine); the deprecation-shim and
bit-exactness-vs-pre-redesign coverage lives in tests/test_gemm_spec.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ops import GemmMasks, GemmSpec


def _mk(m, k, n, dtype, sparsity, key=0):
    rng = np.random.default_rng(key)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    a *= rng.random((m, k)) > sparsity
    mask = (rng.random((m, n)) > sparsity).astype(np.float32)
    return (jnp.asarray(a, dtype), jnp.asarray(b, dtype), jnp.asarray(mask))


SHAPES = [(16, 16, 16), (48, 40, 56), (33, 17, 65), (128, 64, 128)]
BLOCKS = [(8, 8, 8), (16, 16, 16), (16, 8, 32)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("block", BLOCKS[:2])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_sweep(shape, block, dtype):
    m, k, n = shape
    a, b, mask = _mk(m, k, n, dtype, 0.5)
    bm, bk, bn = block
    om = ref.block_any_nonzero(jnp.pad(mask, ((0, -m % bm), (0, -n % bn))), bm, bn)
    got = ops.sparse_gemm(a, b, GemmMasks(out=om), GemmSpec(block=block))
    want = ref.masked_matmul(
        jnp.pad(a, ((0, -m % bm), (0, -k % bk))).astype(jnp.float32),
        jnp.pad(b, ((0, -k % bk), (0, -n % bn))).astype(jnp.float32),
        out_mask=om, bm=bm, bk=bk, bn=bn)[:m, :n]
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("block", BLOCKS)
@pytest.mark.parametrize("schedule", ["predicated", "compact"])
def test_relu_bwd_masked_exact(block, schedule):
    """The paper's core op: (dy @ Wᵀ) ⊙ σ'(z) with skipping == dense."""
    m, k, n = 40, 24, 48
    dy, w, mask = _mk(m, k, n, jnp.float32, 0.6, key=3)
    got = ops.relu_bwd_masked(
        dy, w, mask, spec=GemmSpec(block=block, schedule=schedule))
    want = ref.relu_bwd_masked(dy, w, mask, bm=block[0], bk=block[1],
                               bn=block[2])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # skipped entries must be EXACT zeros (losslessness)
    assert np.all(np.asarray(got)[np.asarray(mask) == 0] == 0.0)


def test_input_sparsity_skip_is_exact():
    """Zero operand tiles contribute exactly nothing."""
    m, k, n = 32, 32, 32
    a, b, _ = _mk(m, k, n, jnp.float32, 0.0, key=5)
    a = a.at[:16].set(0.0)  # entire block row zero
    am = ref.block_any_nonzero(a, 16, 16)
    got = ops.sparse_gemm(a, b, GemmMasks(a=am),
                          GemmSpec(block=(16, 16, 16)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)


def test_weight_grad_masked_both_operands():
    x = jnp.asarray(np.random.default_rng(7).standard_normal((64, 48)),
                    jnp.float32)
    x = x * (jnp.abs(x) > 0.8)            # sparse
    dy = jnp.asarray(np.random.default_rng(8).standard_normal((64, 32)),
                     jnp.float32)
    dy = dy * (jnp.abs(dy) > 0.5)
    got = ops.weight_grad_masked(x.T, dy, spec=GemmSpec(block=(16, 16, 16)))
    np.testing.assert_allclose(got, x.T @ dy, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(16, 16), (40, 56), (33, 65)])
@pytest.mark.parametrize("block", [(8, 8), (16, 16)])
def test_relu_encode_kernel(shape, block):
    rng = np.random.default_rng(11)
    z = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    z = z * (rng.random(shape) > 0.7)     # mostly zero/negative
    y, bitmap = ops.relu_encode(z, block=block)
    yr, br = ref.relu_encode(
        jnp.pad(z, ((0, -shape[0] % block[0]), (0, -shape[1] % block[1]))),
        bm=block[0], bn=block[1])
    np.testing.assert_array_equal(y, jnp.maximum(z, 0))
    np.testing.assert_array_equal(bitmap, br)


def test_compact_queue_matches_predicated():
    """WR (compacted) schedule computes the same thing as predicated."""
    m, k, n = 64, 32, 64
    a, b, mask = _mk(m, k, n, jnp.float32, 0.7, key=13)
    bm = ref.block_any_nonzero(mask, 16, 16)
    spec = GemmSpec(block=(16, 16, 16))
    r1 = ops.sparse_gemm(a, b, GemmMasks(out=bm), spec)
    r2 = ops.sparse_gemm(a, b, GemmMasks(out=bm),
                         spec.with_(schedule="compact"))
    np.testing.assert_allclose(r1, r2, rtol=1e-6, atol=1e-6)


def test_compact_capacity_bound():
    """max_active_blocks caps the queue; with capacity ≥ active it is exact."""
    m = n = k = 32
    a, b, mask = _mk(m, k, n, jnp.float32, 0.8, key=17)
    bmap = ref.block_any_nonzero(mask, 8, 8)
    n_active = int(np.asarray(bmap).sum())
    got = ops.sparse_gemm(
        a, b, GemmMasks(out=bmap),
        GemmSpec(block=(8, 8, 8), schedule="compact",
                 max_active_blocks=n_active))
    want = ref.masked_matmul(a, b, out_mask=bmap, bm=8, bk=8, bn=8)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_compact_queue_overflow_falls_back_exact():
    """Regression: live tiles > queue capacity used to be silently DROPPED
    (n_active = min(live, cap)), producing wrong results with no error.
    Now overflow is detected at runtime and the call falls back to the
    predicated schedule — results stay exact."""
    m = n = k = 32
    a, b, _ = _mk(m, k, n, jnp.float32, 0.0, key=19)   # fully dense
    bmap = jnp.ones((4, 4), jnp.int32)                 # 16 live tiles
    spec = GemmSpec(block=(8, 8, 8), schedule="compact",
                    max_active_blocks=3)               # cap 3 < 16
    got = ops.sparse_gemm(a, b, GemmMasks(out=bmap), spec)
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)
    # ...and under jit (the overflow check is a traced-value cond)
    f = jax.jit(lambda a, b: ops.sparse_gemm(
        a, b, GemmMasks(out=bmap), spec.with_(interpret=True)))
    np.testing.assert_allclose(f(a, b), a @ b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("schedule", ["predicated", "compact"])
def test_epilogue_mult_fused_matches_oracle(schedule):
    """The σ'-Hadamard epilogue inside the kernel == separate multiply."""
    m, k, n = 40, 24, 48
    a, b, mask = _mk(m, k, n, jnp.float32, 0.6, key=23)
    om = ref.block_any_nonzero(mask, 8, 16)
    got = ops.sparse_gemm(
        a, b, GemmMasks(out=om),
        GemmSpec(block=(8, 8, 16), schedule=schedule,
                 epilogue="sigma_prime"),
        epilogue_mult=mask)
    want = ref.masked_matmul(a, b, out_mask=om, bm=8, bk=8, bn=16,
                             epilogue_mult=mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # epilogue zeros are exact
    assert np.all(np.asarray(got)[np.asarray(mask) == 0] == 0.0)
