"""The compaction contract: the Pallas prefix-sum queue builder emits
EXACTLY the WDU reference order (``core.workredist.static_queue_order`` —
row-major "lexicographically smallest state tuple first"), bit-for-bit,
for any bitmap — and the compact matmul path never sorts on the default
policy and never truncates on overflow.

Deterministic sweeps run everywhere (tier-1); the hypothesis suite (random
bitmaps incl. all-zero / all-one / single-row / ragged shapes) needs the
``dev`` extra and skips cleanly without it, mirroring
tests/test_sparsity_properties.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.core.sparse_linear import relu_matmul
from repro.core.workredist import static_queue_order, wdu_dispatch_order
from repro.kernels import ops, ref, stats
from repro.kernels.ops import GemmMasks, GemmSpec

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extra not installed
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed (dev extra)")

if HAS_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


def _assert_queue_equals_reference(bm_np: np.ndarray, capacity: int,
                                   builder: str):
    ii, jj, nl = ops.build_queue(
        jnp.asarray(bm_np, jnp.int32), capacity=capacity, builder=builder)
    ri, rj, rn = static_queue_order(bm_np, capacity=capacity)
    assert int(np.asarray(nl)[0]) == rn
    np.testing.assert_array_equal(np.asarray(ii), ri)
    np.testing.assert_array_equal(np.asarray(jj), rj)


# ---------------------------------------------------------------------------
# deterministic contract sweeps (run without hypothesis)
# ---------------------------------------------------------------------------

EDGE_BITMAPS = [
    np.zeros((4, 4), np.int32),                      # all-zero
    np.ones((4, 4), np.int32),                       # all-one
    np.ones((1, 13), np.int32),                      # single row
    np.ones((11, 1), np.int32),                      # single column
    np.eye(6, dtype=np.int32),                       # diagonal
    (np.indices((5, 9)).sum(0) % 2).astype(np.int32),  # checkerboard
    np.asarray([[0, 1, 1], [1, 0, 0], [0, 0, 1],
                [1, 1, 1], [0, 0, 0]], np.int32),    # ragged rows
]


@pytest.mark.parametrize("builder", ["prefix_sum", "argsort"])
@pytest.mark.parametrize("bm", EDGE_BITMAPS, ids=lambda b: f"{b.shape}")
def test_builders_match_wdu_reference(bm, builder):
    _assert_queue_equals_reference(bm, capacity=bm.size, builder=builder)
    # under-capacity: the first `cap` live slots are preserved, and the
    # returned live count is the TRUE count (the overflow signal)
    _assert_queue_equals_reference(bm, capacity=max(1, bm.size // 3),
                                   builder=builder)


def test_reference_order_is_the_wdu_dispatch_rule():
    bm = (np.indices((7, 6)).sum(0) % 3 == 0).astype(np.int32)
    ii, jj, n = static_queue_order(bm)
    assert list(zip(ii[:n], jj[:n])) == wdu_dispatch_order(bm)


def test_compact_default_policy_builds_queue_with_zero_argsorts():
    """ACCEPTANCE: the compact schedule constructs its queue with zero
    argsort calls on the default (prefix_sum) spec — asserted via the
    kernels.stats counter."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    om = jnp.asarray(rng.random((4, 4)) > 0.5, jnp.int32)
    stats.reset()
    out = ops.sparse_gemm(a, b, GemmMasks(out=om),
                          GemmSpec(block=(8, 8, 8), schedule="compact"))
    assert stats.queue_builds("argsort") == 0, stats.counts()
    assert stats.queue_builds("prefix_sum") == 1, stats.counts()
    assert stats.gemm_launches(schedule="compact", groups=1) == 1
    want = ref.masked_matmul(a, b, out_mask=om, bm=8, bk=8, bn=8)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_end_to_end_training_step_never_sorts_on_default_policy():
    """The whole fwd+bwd of the fused unit under IN_OUT_WR: queues are
    built (compact schedule), none of them by sorting."""
    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    stats.reset()
    jax.grad(lambda x, w: (relu_matmul(x, w, policy) ** 2).sum(), (0, 1))(x, w)
    assert stats.queue_builds() > 0, stats.counts()
    assert stats.queue_builds("argsort") == 0, stats.counts()


@pytest.mark.parametrize("builder", ["prefix_sum", "argsort"])
def test_compact_matmul_same_result_for_both_builders(builder):
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((40, 24)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((24, 48)), jnp.float32)
    mask = (rng.random((40, 48)) > 0.6).astype(np.float32)
    om = ref.block_any_nonzero(jnp.asarray(mask), 8, 16)
    spec = GemmSpec(block=(8, 8, 16))
    got = ops.sparse_gemm(
        a, b, GemmMasks(out=om),
        spec.with_(schedule="compact", queue_builder=builder))
    want = ops.sparse_gemm(a, b, GemmMasks(out=om), spec)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("builder", ["prefix_sum", "argsort"])
def test_overflow_falls_back_bit_exactly_to_predicated(builder):
    """REGRESSION: n_live > max_active_blocks must route to the predicated
    schedule — the result is bit-identical to calling it directly."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    om = jnp.ones((4, 4), jnp.int32)                  # 16 live tiles
    spec = GemmSpec(block=(8, 8, 8), schedule="compact",
                    max_active_blocks=3, queue_builder=builder)
    got = ops.sparse_gemm(a, b, GemmMasks(out=om), spec)
    predicated = ops.sparse_gemm(a, b, GemmMasks(out=om),
                                 GemmSpec(block=(8, 8, 8)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(predicated))
    # ...and under jit, where the live count is a traced value
    f = jax.jit(lambda a, b: ops.sparse_gemm(
        a, b, GemmMasks(out=om), spec.with_(interpret=True)))
    np.testing.assert_array_equal(np.asarray(f(a, b)), np.asarray(predicated))


def test_build_queue_rejects_unknown_builder():
    with pytest.raises(ValueError, match="unknown queue builder"):
        ops.build_queue(jnp.ones((2, 2), jnp.int32), capacity=4,
                        builder="bogosort")


def test_build_queue_jits_and_batches_under_vmap_shapes():
    """The builder must be jit-safe (it sits inside jitted train steps)."""
    bm = jnp.asarray(np.eye(5, dtype=np.int32))
    f = jax.jit(lambda m: ops.build_queue(m, capacity=25, interpret=True))
    ii, jj, nl = f(bm)
    ri, rj, rn = static_queue_order(np.eye(5), capacity=25)
    assert int(nl[0]) == rn
    np.testing.assert_array_equal(np.asarray(ii), ri)
    np.testing.assert_array_equal(np.asarray(jj), rj)


# ---------------------------------------------------------------------------
# hypothesis property suite (dev extra)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:

    @st.composite
    def _bitmap(draw, max_dim=12):
        mb = draw(st.integers(1, max_dim))
        nb = draw(st.integers(1, max_dim))
        kind = draw(st.sampled_from(["random", "zeros", "ones"]))
        if kind == "zeros":
            return np.zeros((mb, nb), np.int32)
        if kind == "ones":
            return np.ones((mb, nb), np.int32)
        seed = draw(st.integers(0, 2 ** 16))
        dens = draw(st.floats(0.0, 1.0))
        rng = np.random.default_rng(seed)
        return (rng.random((mb, nb)) < dens).astype(np.int32)

    @needs_hypothesis
    @given(_bitmap(), st.sampled_from(["prefix_sum", "argsort"]))
    def test_property_queue_equals_reference_full_capacity(bm, builder):
        _assert_queue_equals_reference(bm, capacity=bm.size, builder=builder)

    @needs_hypothesis
    @given(_bitmap(), st.integers(1, 20),
           st.sampled_from(["prefix_sum", "argsort"]))
    def test_property_queue_equals_reference_any_capacity(bm, cap, builder):
        _assert_queue_equals_reference(bm, capacity=cap, builder=builder)

    @needs_hypothesis
    @given(st.integers(0, 2 ** 16), st.floats(0.0, 1.0),
           st.integers(9, 40), st.integers(9, 40))
    def test_property_compact_matmul_exact_ragged_shapes(seed, dens, m, n):
        """Ragged (non-block-multiple) shapes through the full compact
        path: padding tiles are dead, queue is exact, result == oracle."""
        rng = np.random.default_rng(seed)
        k = 16
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        mask = (rng.random((m, n)) < dens).astype(np.float32)
        mp = jnp.asarray(np.pad(mask, ((0, -m % 8), (0, -n % 8))))
        om = ref.block_any_nonzero(mp, 8, 8)
        got = ops.sparse_gemm(a, b, GemmMasks(out=om),
                              GemmSpec(block=(8, 8, 8), schedule="compact"))
        want = (np.asarray(a) @ np.asarray(b)) * \
            np.asarray(ref.expand_block_mask(om, 8, 8))[:m, :n]
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @needs_hypothesis
    @given(_bitmap(max_dim=6), st.integers(0, 2 ** 16))
    def test_property_overflow_fallback_is_bit_exact(bm, seed):
        n_live = int(bm.sum())
        if n_live < 2:
            return                      # cannot under-provision the queue
        cap = n_live - 1                # guaranteed overflow
        mb, nb = bm.shape
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.standard_normal((mb * 8, 8)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((8, nb * 8)), jnp.float32)
        got = ops.sparse_gemm(
            a, b, GemmMasks(out=jnp.asarray(bm)),
            GemmSpec(block=(8, 8, 8), schedule="compact",
                     max_active_blocks=cap))
        predicated = ops.sparse_gemm(a, b, GemmMasks(out=jnp.asarray(bm)),
                                     GemmSpec(block=(8, 8, 8)))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(predicated))
