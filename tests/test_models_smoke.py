"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + finiteness (required by the assignment brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_ARCHS, ALL_SHAPES, runs_cell
from repro.models.transformer import (decode_step, init_caches, lm_init,
                                      lm_loss)

ARCH_NAMES = list(SMOKE_ARCHS)


def _batch(cfg, b=2, t=16):
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (b, t + 1), 0, cfg.vocab_size)}
    if cfg.enc_dec or cfg.frontend:
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.key(2), (b, cfg.frontend_len, cfg.frontend_dim),
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    cfg = SMOKE_ARCHS[name]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    loss, grads = jax.value_and_grad(lm_loss)(params, _batch(cfg), cfg)
    assert np.isfinite(float(loss)), name
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_smoke(name):
    cfg = SMOKE_ARCHS[name]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    b = 2
    caches = init_caches(cfg, b, 16, jnp.float32)
    token = jnp.zeros((b,), jnp.int32)
    memory = (jax.random.normal(jax.random.key(3), (b, 8, cfg.d_model))
              if cfg.enc_dec else None)
    logits, caches2 = decode_step(params, token, caches, jnp.asarray(0),
                                  cfg, memory=memory)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    # cache pytree structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_dims_match_assignment(name):
    """The FULL configs carry the exact assigned dimensions."""
    spec = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }[name]
    cfg = ARCHS[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (name, got, spec)


def test_moe_structure():
    assert ARCHS["grok-1-314b"].moe.n_experts == 8
    assert ARCHS["grok-1-314b"].moe.top_k == 2
    ds = ARCHS["deepseek-v2-lite-16b"]
    assert ds.moe.n_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.n_shared_experts == 2 and ds.use_mla
    assert ds.kv_lora_rank == 512 and ds.n_dense_layers == 1
    jm = ARCHS["jamba-1.5-large-398b"]
    assert jm.moe.n_experts == 16 and jm.moe.top_k == 2
    assert jm.pattern.count("M") == 7 and jm.pattern.count("A") == 1


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md table)."""
    long = [s for s in ALL_SHAPES if s.name == "long_500k"][0]
    expect_ok = {"xlstm-350m", "jamba-1.5-large-398b", "gemma3-12b"}
    for name, cfg in ARCHS.items():
        ok, _ = runs_cell(cfg, long)
        assert ok == (name in expect_ok), name


def test_decode_matches_parallel_forward():
    """T decode steps == one-shot forward (cache correctness), dense arch."""
    from repro.models.transformer import lm_hidden, lm_head_weight
    cfg = SMOKE_ARCHS["smollm-360m"]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    b, t = 2, 8
    toks = jax.random.randint(jax.random.key(5), (b, t), 0, cfg.vocab_size)
    h, _ = lm_hidden(params, toks, cfg)
    w = lm_head_weight(params, cfg)
    want = h[:, -1].astype(jnp.float32) @ w.astype(jnp.float32)
    caches = init_caches(cfg, b, t, jnp.float32)
    for i in range(t):
        logits, caches = decode_step(params, toks[:, i], caches,
                                     jnp.asarray(i, jnp.int32), cfg)
    np.testing.assert_allclose(logits, want, rtol=2e-3, atol=2e-3)


def test_decode_matches_parallel_forward_sliding_window():
    cfg = SMOKE_ARCHS["gemma3-12b"]
    from repro.models.transformer import lm_hidden, lm_head_weight
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    b, t = 2, 12
    toks = jax.random.randint(jax.random.key(6), (b, t), 0, cfg.vocab_size)
    h, _ = lm_hidden(params, toks, cfg)
    w = lm_head_weight(params, cfg)
    want = h[:, -1].astype(jnp.float32) @ w.astype(jnp.float32)
    caches = init_caches(cfg, b, t, jnp.float32)
    for i in range(t):
        logits, caches = decode_step(params, toks[:, i], caches,
                                     jnp.asarray(i, jnp.int32), cfg)
    np.testing.assert_allclose(logits, want, rtol=2e-3, atol=2e-3)


def test_decode_matches_parallel_forward_ssm():
    """Mamba recurrence == chunked parallel scan (jamba hybrid).

    capacity_factor is raised so no token drops: decode routes 2 tokens
    while the parallel forward routes 16, and drop sets differ at the
    default capacity (expected behaviour, not a bug — GShard semantics)."""
    import dataclasses
    base = SMOKE_ARCHS["jamba-1.5-large-398b"]
    cfg = base.with_(moe=dataclasses.replace(base.moe, capacity_factor=16.0))
    from repro.models.transformer import lm_hidden, lm_head_weight
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    b, t = 2, 8
    toks = jax.random.randint(jax.random.key(7), (b, t), 0, cfg.vocab_size)
    h, _ = lm_hidden(params, toks, cfg)
    w = lm_head_weight(params, cfg)
    want = h[:, -1].astype(jnp.float32) @ w.astype(jnp.float32)
    caches = init_caches(cfg, b, t, jnp.float32)
    for i in range(t):
        logits, caches = decode_step(params, toks[:, i], caches,
                                     jnp.asarray(i, jnp.int32), cfg)
    np.testing.assert_allclose(logits, want, rtol=5e-3, atol=5e-3)
