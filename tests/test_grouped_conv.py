"""Grouped/depthwise convs through the unified conv engine.

The engine lowers every conv — dense, fused-ReLU, grouped, depthwise —
onto the same masked-GEMM dispatch, so the paper's exactness claim must
hold per group: gradients bit-match ``lax.conv_general_dilated`` autodiff
for stride ∈ {1, 2}, padding ∈ {SAME, VALID}, groups ∈ {2, C}, on the
pallas (compact × fused-epilogue), xla_ref, and DC paths.  Plus the
group-boundary granularity contract and the degenerate block-shape rule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.core.policy import grouped_gemm_block
from repro.core.sparse_conv import (
    conv as sconv, depthwise_conv, depthwise_relu_conv, relu_conv,
)
from repro.core.sparse_tensor import conv_channel_granularity
from repro.kernels import stats

PALLAS = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 16, 8))
PALLAS_U = pol.IN_OUT.with_(kernel_impl="pallas", block=(16, 16, 16))
C, M = 6, 12     # channels divisible by both group counts under test


def _rand(shape, key, sparsify=0.0):
    rng = np.random.default_rng(key)
    x = rng.standard_normal(shape).astype(np.float32)
    if sparsify:
        x *= rng.random(shape) > sparsify
    return jnp.asarray(x)


def _dense(x, w, stride, padding, groups, relu):
    xx = jnp.maximum(x, 0) if relu else x
    return jax.lax.conv_general_dilated(
        xx, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID"), (2, "VALID")])
@pytest.mark.parametrize("groups", [2, C])
@pytest.mark.parametrize("policy", [PALLAS, PALLAS_U, pol.IN_OUT, pol.DC])
def test_grouped_relu_conv_grads_exact(stride, padding, groups, policy):
    x = _rand((2, 9, 11, C), 1)
    w = _rand((3, 3, C // groups, M), 2)
    f = lambda x, w: (relu_conv(x, w, stride, padding, policy,
                                groups=groups) ** 2).sum()
    g = lambda x, w: (_dense(x, w, stride, padding, groups, True) ** 2).sum()
    np.testing.assert_allclose(f(x, w), g(x, w), rtol=1e-4)
    for a, b in zip(jax.grad(f, (0, 1))(x, w), jax.grad(g, (0, 1))(x, w)):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "VALID")])
@pytest.mark.parametrize("groups", [2, C])
def test_grouped_plain_conv_grads_exact(stride, padding, groups):
    """Signed-input grouped conv (post-pool boundary): no fused ReLU."""
    x = _rand((2, 8, 8, C), 3)
    w = _rand((3, 3, C // groups, M), 4)
    f = lambda x, w: (sconv(x, w, stride, padding, PALLAS_U,
                            groups=groups) ** 2).sum()
    g = lambda x, w: (_dense(x, w, stride, padding, groups, False) ** 2).sum()
    np.testing.assert_allclose(f(x, w), g(x, w), rtol=1e-4)
    for a, b in zip(jax.grad(f, (0, 1))(x, w), jax.grad(g, (0, 1))(x, w)):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("policy", [PALLAS, PALLAS.with_(fuse_epilogue=False),
                                    PALLAS.with_(queue_builder="argsort")])
def test_depthwise_relu_conv_grads_exact(stride, policy):
    """groups == C through the convenience wrapper, compact schedule and
    both σ′-epilogue modes — the MobileNet dw cell."""
    c = 8
    x = _rand((2, 8, 8, c), 5)
    w = _rand((3, 3, 1, c), 6)
    f = lambda x, w: (depthwise_relu_conv(x, w, stride, "SAME",
                                          policy) ** 2).sum()
    g = lambda x, w: (_dense(x, w, stride, "SAME", c, True) ** 2).sum()
    np.testing.assert_allclose(f(x, w), g(x, w), rtol=1e-4)
    for a, b in zip(jax.grad(f, (0, 1))(x, w), jax.grad(g, (0, 1))(x, w)):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)
    # masked-out channels of dx are EXACT zeros (per-group epilogue
    # losslessness — zeros of one group must not leak into another).
    dx = jax.grad(f, 0)(x, w)
    assert np.all(np.asarray(dx)[np.asarray(x) < 0] == 0.0)


def test_depthwise_plain_conv_grads_exact():
    c = 8
    x = _rand((2, 8, 8, c), 7)
    w = _rand((3, 3, 1, c), 8)
    f = lambda x, w: (depthwise_conv(x, w, 1, "SAME", PALLAS_U) ** 2).sum()
    g = lambda x, w: (_dense(x, w, 1, "SAME", c, False) ** 2).sum()
    np.testing.assert_allclose(f(x, w), g(x, w), rtol=1e-4)
    for a, b in zip(jax.grad(f, (0, 1))(x, w), jax.grad(g, (0, 1))(x, w)):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Grouped kernel vs pure-jnp oracle (kernels/ref.py) — all schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compact", [False, True])
def test_grouped_masked_matmul_matches_oracle(compact):
    from repro.kernels import ops as kops, ref as kref

    rng = np.random.default_rng(11)
    g, m, k, n = 3, 13, 9, 5
    bm, bk, bn = 4, 8, 4
    mp, kp, np_ = 16, 16, 8
    a = jnp.asarray(rng.standard_normal((g, m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)

    def pad3(x, d1, d2):
        return jnp.pad(x, ((0, 0), (0, d1 - x.shape[1]),
                           (0, d2 - x.shape[2])))

    om = jnp.asarray(rng.random((g, mp // bm, np_ // bn)) > 0.3, jnp.int32)
    am = jnp.asarray(rng.random((g, mp // bm, kp // bk)) > 0.2, jnp.int32)
    bmask = jnp.asarray(rng.random((g, kp // bk, np_ // bn)) > 0.2, jnp.int32)
    mult = jnp.asarray(rng.random((g, m, n)) > 0.5, jnp.float32)

    got = kops.sparse_gemm(
        a, b, kops.GemmMasks(om, am, bmask),
        kops.GemmSpec(block=(bm, bk, bn), groups=g,
                      schedule="compact" if compact else "predicated",
                      epilogue="sigma_prime"),
        epilogue_mult=mult)
    want = kref.grouped_masked_matmul(
        pad3(a, mp, kp), pad3(b, kp, np_), om, am, bmask,
        bm=bm, bk=bk, bn=bn,
        epilogue_mult=pad3(mult, mp, np_))[:, :m, :n]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_grouped_compact_bounded_queue_and_overflow():
    """Exactly-live capacity stays exact; one-below-live triggers the
    grouped predicated fallback — never a silent truncation."""
    from repro.kernels import ops as kops, ref as kref

    rng = np.random.default_rng(12)
    g, m, k, n = 3, 16, 16, 8
    bm, bk, bn = 4, 8, 4
    a = jnp.asarray(rng.standard_normal((g, m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((g, k, n)), jnp.float32)
    om = jnp.asarray(rng.random((g, m // bm, n // bn)) > 0.4, jnp.int32)
    want = kref.grouped_masked_matmul(a, b, om, None, None,
                                      bm=bm, bk=bk, bn=bn)
    n_live = int(np.asarray(om).sum())
    for cap in (n_live, max(1, n_live - 2)):
        got = kops.sparse_gemm(
            a, b, kops.GemmMasks(out=om),
            kops.GemmSpec(block=(bm, bk, bn), groups=g, schedule="compact",
                          max_active_blocks=cap))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Contracts: group-boundary granularity + degenerate block shapes
# ---------------------------------------------------------------------------

def test_channel_granularity_respects_group_boundaries():
    """A coarsened cell must never straddle two groups: gc | C//G."""
    for block in [(8, 16, 8), (16, 16, 16), (128, 128, 128)]:
        bm, bk, bn = block
        for channels, groups in [(6, 2), (6, 6), (64, 64), (64, 4), (12, 3)]:
            g = conv_channel_granularity(channels, block, groups)
            per_group = channels // groups
            assert per_group % g == 0, (channels, groups, block, g)
            assert bm % g == 0 and bk % g == 0 and bn % g == 0
    # depthwise degenerates to per-channel granularity
    assert conv_channel_granularity(64, (128, 128, 128), 64) == 1


def test_grouped_gemm_block_degenerates_for_tiny_dims():
    """Depthwise K = R·S·1 = 9: the engine must pick a ~K-sized block, not
    pad a 128-block that can never mask ("silently masking nothing")."""
    p = pol.IN_OUT.with_(block=(128, 128, 128))
    bm, bk, bn = grouped_gemm_block(p, (4096, 9, 1), (1, 1, 1))
    assert bk == 9 and bn == 1 and bm == 128
    # granularity keeps edges aligned: K gran 4 rounds the edge up
    bm, bk, bn = grouped_gemm_block(p, (4096, 18, 8), (1, 4, 4))
    assert bk % 4 == 0 and bk >= 18 and bk <= 20
    assert bn == 8
    # large per-group dims keep the nominal MXU tile
    assert grouped_gemm_block(p, (4096, 1152, 256), (1, 1, 1)) \
        == (128, 128, 128)
    # explicit grouped_block override wins over `block`
    p2 = p.with_(grouped_block=(32, 16, 16))
    assert grouped_gemm_block(p2, (4096, 1152, 256), (1, 1, 1)) \
        == (32, 16, 16)


def test_grouped_sparsity_min_k_threshold():
    """The policy knob drops operand masks below the per-group-K threshold
    without changing results (masks are an optimization, not semantics)."""
    c = 8
    x = _rand((2, 8, 8, c), 9)
    w = _rand((3, 3, 1, c), 10)
    hi = PALLAS_U.with_(grouped_sparsity_min_k=1000)   # masks disabled
    f_lo = lambda x, w: (depthwise_relu_conv(x, w, 1, "SAME",
                                             PALLAS_U) ** 2).sum()
    f_hi = lambda x, w: (depthwise_relu_conv(x, w, 1, "SAME", hi) ** 2).sum()
    np.testing.assert_allclose(f_lo(x, w), f_hi(x, w), rtol=1e-5)
    for a, b in zip(jax.grad(f_lo, (0, 1))(x, w),
                    jax.grad(f_hi, (0, 1))(x, w)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_mobilenet_smoke_no_dense_fallbacks():
    """MobileNet's 13 dw layers route through the sparse engine: zero
    ``conv:dense_fallback`` records in a full fwd+bwd step under the
    default pallas policy — the ISSUE's acceptance criterion."""
    from repro.data.pipeline import image_batch
    from repro.models.cnn import build_cnn

    model = build_cnn("mobilenet", image_size=8, width=0.0625, num_classes=10)
    params = model.init(jax.random.key(0))
    img, labels = image_batch(0, 0, batch=1, image_size=8, num_classes=10)
    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    stats.reset()
    grads = jax.grad(lambda p: model.loss(p, img, labels, policy))(params)
    counts = stats.counts()
    assert counts.get("conv:dense_fallback", 0) == 0, counts
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_depthwise_init_is_pure():
    """The IR is never mutated: init → conv_specs → re-init agree in any
    order (the seed wrote ``node.out_ch = in_ch`` during init, so a
    conv_specs call before init disagreed with one after)."""
    from repro.models.cnn import build_cnn

    m1 = build_cnn("mobilenet", image_size=16, width=0.25, num_classes=10)
    specs_before = [(s.name, s.c, s.m, s.groups) for s in m1.conv_specs(2)]
    params = m1.init(jax.random.key(0))
    specs_after = [(s.name, s.c, s.m, s.groups) for s in m1.conv_specs(2)]
    assert specs_before == specs_after
    for node_name, _, out_ch, _ in specs_before:
        assert params[node_name]["w"].shape[3] == out_ch
    # the IR itself still carries the unresolved sentinel
    dw_nodes = [n for n in m1.layers
                if getattr(n, "depthwise", False)]
    assert dw_nodes and all(n.out_ch == 0 for n in dw_nodes)
    # re-init from the same key is bit-identical (no state left behind)
    params2 = m1.init(jax.random.key(0))
    for k in params:
        np.testing.assert_array_equal(params[k]["w"], params2[k]["w"])
