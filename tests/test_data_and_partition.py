"""Data pipeline determinism + partition-rule validity for every arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.configs.base import ALL_SHAPES
from repro.configs.registry import decode_input_specs, train_input_specs
from repro.data.pipeline import image_batch, lm_batch
from repro.launch.mesh import make_abstract_mesh
from repro.models.transformer import lm_init
from repro.sharding import partition


def test_lm_batch_deterministic_and_shard_disjoint():
    b1 = lm_batch(0, 5, batch=8, seq_len=16, vocab=97)
    b2 = lm_batch(0, 5, batch=8, seq_len=16, vocab=97)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    s0 = lm_batch(0, 5, batch=8, seq_len=16, vocab=97, shard_index=0,
                  shard_count=2)
    s1 = lm_batch(0, 5, batch=8, seq_len=16, vocab=97, shard_index=1,
                  shard_count=2)
    assert s0["tokens"].shape == (4, 17)
    assert not np.array_equal(np.asarray(s0["tokens"]),
                              np.asarray(s1["tokens"]))


def test_lm_batch_is_learnable_structure():
    """Next token is (mostly) an affine function of the current one."""
    b = lm_batch(1, 0, batch=32, seq_len=64, vocab=101, noise=0.0)
    toks = np.asarray(b["tokens"])
    # Check the recurrence holds for each row with some (a, c)
    for row in toks[:4]:
        diffs = set()
        for a in range(1, 17):
            c = (row[1] - a * row[0]) % 101
            if np.all((a * row[:-1] + c) % 101 == row[1:]):
                diffs.add((a, c))
        assert diffs, "no affine recurrence found"


def test_image_batch_zero_mean():
    img, labels = image_batch(0, 0, batch=4, image_size=16)
    assert img.shape == (4, 16, 16, 3)
    np.testing.assert_allclose(np.asarray(img).mean(axis=(1, 2, 3)), 0,
                               atol=1e-5)


MESHES = [
    make_abstract_mesh((16, 16), ("data", "model")),
    make_abstract_mesh((2, 16, 16), ("pod", "data", "model")),
]


@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod"])
@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_specs_divisible_every_arch(arch, mesh):
    """Every generated PartitionSpec evenly divides its dimension — the
    divisibility-guard property that lets one rule table serve all archs."""
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(lambda: lm_init(jax.random.key(0), cfg))
    specs = partition.params_pspecs(shapes, mesh, fsdp=True)
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (kp, leaf), spec in zip(flat_s, flat_p):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            size = partition.axis_size(mesh, ax)
            assert dim % size == 0, (jax.tree_util.keystr(kp), leaf.shape,
                                     spec)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_cache_specs_divisible_every_arch(arch):
    mesh = MESHES[1]
    cfg = ARCHS[arch]
    for shape in ALL_SHAPES:
        if shape.kind != "decode":
            continue
        specs = decode_input_specs(cfg, shape)
        cspecs = partition.cache_pspecs(specs["caches"], mesh)
        flat_s = jax.tree_util.tree_flatten_with_path(specs["caches"])[0]
        flat_p = jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P))
        for (kp, leaf), spec in zip(flat_s, flat_p):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                assert dim % partition.axis_size(mesh, ax) == 0, \
                    (jax.tree_util.keystr(kp), leaf.shape, spec)


def test_long_context_cache_uses_sequence_parallelism():
    """batch=1 long-context decode shards the KV sequence dim on data (SP)."""
    mesh = MESHES[0]
    cfg = ARCHS["jamba-1.5-large-398b"]
    shape = [s for s in ALL_SHAPES if s.name == "long_500k"][0]
    specs = decode_input_specs(cfg, shape)
    cspecs = partition.cache_pspecs(specs["caches"], mesh)
    # find an attention kv cache leaf: (periods, B=1, S, H, D)
    flat = jax.tree_util.tree_flatten_with_path(specs["caches"])[0]
    ps = jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P))
    found_sp = False
    for (kp, leaf), spec in zip(flat, ps):
        path = jax.tree_util.keystr(kp)
        if "'k'" in path and leaf.ndim == 5:
            # seq dim (index 2) should carry the data axes
            if spec[2] is not None:
                found_sp = True
    assert found_sp


def test_fsdp_reduces_resident_bytes():
    mesh = MESHES[0]
    cfg = ARCHS["grok-1-314b"]
    shapes = jax.eval_shape(lambda: lm_init(jax.random.key(0), cfg))

    def resident(specs):
        tot = 0
        for leaf, spec in zip(
                jax.tree.leaves(shapes),
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
            n = int(np.prod(leaf.shape))
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is not None:
                    n //= partition.axis_size(mesh, ax)
            tot += n
        return tot

    no = resident(partition.params_pspecs(shapes, mesh, fsdp=False))
    yes = resident(partition.params_pspecs(shapes, mesh, fsdp=True))
    assert yes < no / 8          # ≥8× fewer resident elements with FSDP
