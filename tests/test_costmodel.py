"""Cost-model and WDU tests: the paper's scenario ordering and rules."""
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import workredist as wr


def _spec(**kw):
    base = dict(name="l", c=128, h=28, w=28, m=128, r=3, s=3, batch=16)
    base.update(kw)
    return cm.ConvSpec(**base)


def _trace(x=0.5, g=0.5, o=0.5, seed=0):
    # per-location work is a sum over a C·R·S receptive field, so its
    # spatial variance is modest (law of large numbers) — model that.
    rng = np.random.default_rng(seed)
    return cm.LayerTrace(x_density=x, g_in_density=g, out_mask_density=o,
                         bp_active_map=0.5 + 0.15 * rng.random((28, 28)))


def test_scenario_ordering():
    """DC ≥ IN ≥ IN_OUT ≥ IN_OUT_WR total cycles (paper Figs. 11–15)."""
    spec, tr = _spec(), _trace()
    c = {s: cm.layer_cost(spec, tr, s).total_cycles
         for s in ("DC", "IN", "IN_OUT", "IN_OUT_WR")}
    assert c["DC"] >= c["IN"] >= c["IN_OUT"] >= c["IN_OUT_WR"]
    assert c["DC"] / c["IN_OUT_WR"] > 1.5     # meaningful gains at 50%


def test_bn_blocks_input_sparsity_not_output():
    """Fig. 3c: with BN, the incoming gradient is dense (g_in_density=1) so
    IN gives no BP gain, but OUT still does."""
    spec = _spec(has_bn=True)
    tr = cm.LayerTrace(x_density=0.5, g_in_density=1.0, out_mask_density=0.5)
    dc = cm.layer_cost(spec, tr, "DC").bp.cycles
    inp = cm.layer_cost(spec, tr, "IN").bp.cycles
    out = cm.layer_cost(spec, tr, "IN_OUT").bp.cycles
    assert inp == pytest.approx(dc)           # input sparsity: no BP benefit
    assert out < 0.6 * dc                     # output sparsity still works


def test_non_relu_producer_disables_output_sparsity():
    """MaxPool→CONV boundary (Fig. 11 bars 3/5/8/11): no OUT benefit."""
    spec = _spec(input_is_relu=False)
    tr = _trace()
    bp_in = cm.layer_cost(spec, tr, "IN").bp.cycles
    bp_out = cm.layer_cost(spec, tr, "IN_OUT").bp.cycles
    assert bp_out == pytest.approx(bp_in)


def test_lane_utilization_modes():
    """Fig. 16: hierarchical reconfiguration recovers small-CRS utilization."""
    hw = cm.DEFAULT_HW
    crs_small = 64          # 1x1x64 → 2/16 lanes
    none = cm.lane_utilization(crs_small, hw, "none")
    direct = cm.lane_utilization(crs_small, hw, "direct")
    hier = cm.lane_utilization(crs_small, hw, "hierarchical")
    assert none < direct <= 1.0
    assert hier > 0.9
    # 3x3x64 = 576 → 9/16 lanes occupancy, direct replication can't help
    crs9 = 576
    assert cm.lane_utilization(crs9, hw, "direct") < 0.6
    assert cm.lane_utilization(crs9, hw, "hierarchical") > 0.9
    # CRS > capacity: synapse blocking ceil waste only
    assert cm.lane_utilization(2048, hw) == pytest.approx(1.0)
    assert cm.lane_utilization(1536, hw) == pytest.approx(0.75)


def test_wdu_improves_utilization_and_makespan():
    rng = np.random.default_rng(0)
    work = rng.gamma(2.0, 100.0, 256)
    base = wr.simulate(work, redistribute=False)
    with_wr = wr.simulate(work, redistribute=True)
    assert with_wr.makespan < base.makespan
    assert with_wr.utilization > base.utilization
    assert with_wr.n_redistributions > 0
    # conservation: busy time ≈ total work (+ overhead)
    assert with_wr.busy_avg * 256 >= work.sum() * 0.999


def test_wdu_threshold_gates_transfers():
    work = np.full(256, 100.0)
    work[0] = 130.0                       # mild imbalance below threshold
    r = wr.simulate(work, redistribute=True, threshold=0.9)
    assert r.n_redistributions == 0


def test_wdu_all_zero_work_vector():
    """Degenerate layer (nothing to do): time never advances, utilization
    reports the no-op convention 1.0, nothing moves."""
    r = wr.simulate(np.zeros(8), redistribute=True)
    assert r.makespan == 0.0
    assert r.busy_min == r.busy_avg == r.busy_max == 0.0
    assert r.utilization == 1.0
    assert r.n_redistributions == 0


def test_wdu_single_tile():
    """One tile: makespan is its work, full utilization, no peers to help."""
    r = wr.simulate(np.asarray([42.0]), redistribute=True)
    assert r.makespan == pytest.approx(42.0)
    assert r.utilization == pytest.approx(1.0)
    assert r.n_redistributions == 0


def test_wdu_threshold_one_never_redistributes():
    """threshold=1.0: remaining/original < 1 after any progress, so no
    transfer ever fires — makespan degenerates to max(work)."""
    rng = np.random.default_rng(5)
    work = rng.gamma(2.0, 100.0, 64)
    r = wr.simulate(work, redistribute=True, threshold=1.0)
    assert r.n_redistributions == 0
    assert r.makespan == pytest.approx(work.max())
    base = wr.simulate(work, redistribute=False)
    assert r.makespan == pytest.approx(base.makespan)


def test_wdu_split_one_moves_everything():
    """split=1.0 hands the target's whole remainder to the idle tile; the
    invariants must still hold (it terminates, conserves work + overhead)."""
    rng = np.random.default_rng(6)
    work = rng.gamma(2.0, 100.0, 32)
    r = wr.simulate(work, redistribute=True, split=1.0)
    assert r.n_redistributions > 0
    _assert_wdu_invariants(r, work)


def _assert_wdu_invariants(r: "wr.WDUResult", work: np.ndarray):
    # busy-time ordering
    assert r.busy_min <= r.busy_avg + 1e-9
    assert r.busy_avg <= r.busy_max + 1e-9
    assert r.busy_max <= r.makespan + 1e-9
    # work conservation up to the charged transfer overhead: total busy
    # time is the original work plus overhead on moved work.  Each of the
    # k transfers inflates at most the whole remaining pool by (1+o), so
    # (1+o)^k bounds the compounding from above.
    total_busy = r.busy_avg * len(work)
    assert total_busy >= work.sum() * (1 - 1e-9)
    assert total_busy <= work.sum() * (1.02 ** r.n_redistributions) + 1e-6
    assert 0.0 < r.utilization <= 1.0


@pytest.mark.parametrize("threshold,split", [(0.3, 0.5), (0.0, 0.5),
                                             (0.3, 1.0), (1.0, 0.5)])
def test_wdu_invariants_hold_across_knobs(threshold, split):
    rng = np.random.default_rng(7)
    work = rng.gamma(2.0, 100.0, 128)
    r = wr.simulate(work, redistribute=True, threshold=threshold, split=split)
    _assert_wdu_invariants(r, work)


def test_static_queue_order_is_wdu_dispatch_order():
    """The static queue the TPU kernels consume follows the WDU's
    lexicographic dispatch rule exactly (paper §4.6)."""
    rng = np.random.default_rng(8)
    bm = (rng.random((9, 7)) > 0.5).astype(np.int32)
    ii, jj, n = wr.static_queue_order(bm)
    assert n == int(bm.sum())
    assert list(zip(ii[:n], jj[:n])) == wr.wdu_dispatch_order(bm)
    # capacity semantics: truncation keeps the prefix, n stays truthful
    ii2, jj2, n2 = wr.static_queue_order(bm, capacity=3)
    assert n2 == n and len(ii2) == 3
    np.testing.assert_array_equal(ii2, ii[:3])
    np.testing.assert_array_equal(jj2, jj[:3])


def test_tile_work_partition():
    act = np.ones((32, 32))
    tiles = wr.tile_work_from_mask(act, 16, 16, macs_per_output=10.0)
    assert tiles.shape == (256,)
    np.testing.assert_allclose(tiles, 40.0)   # 4 outputs × 10 MACs each


def test_network_cost_aggregates():
    specs = [_spec(name=f"l{i}") for i in range(3)]
    traces = [_trace(seed=i) for i in range(3)]
    out = cm.network_cost(specs, traces, "IN_OUT_WR")
    assert out["total_cycles"] == pytest.approx(
        out["fp_cycles"] + out["bp_cycles"] + out["wg_cycles"])
    assert out["total_energy_j"] > 0
    assert out["iteration_ms"] > 0
