"""CNN zoo (the paper's five benchmarks) + serving engine tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import DC, IN_OUT
from repro.core.sparsity import element_sparsity
from repro.data.pipeline import image_batch
from repro.models.cnn import NETWORKS, build_cnn


@pytest.mark.parametrize("name", list(NETWORKS))
def test_cnn_forward_backward(name):
    model = build_cnn(name, image_size=16, width=0.25, num_classes=10)
    params = model.init(jax.random.key(0))
    img, labels = image_batch(0, 0, batch=2, image_size=16, num_classes=10)
    loss, grads = jax.value_and_grad(model.loss)(params, img, labels)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.parametrize("name", list(NETWORKS))
def test_cnn_activations_are_sparse(name):
    """§3.1: zero-mean inputs + ReLU ⇒ ~30–70% activation sparsity from
    the first training step — the paper's enabling observation."""
    model = build_cnn(name, image_size=16, width=0.25, num_classes=10)
    params = model.init(jax.random.key(0))
    img, _ = image_batch(0, 0, batch=2, image_size=16, num_classes=10)
    cap = {}
    model.apply(params, img, capture=cap)
    assert cap, name
    sp = [float(element_sparsity(v)) for v in cap.values()]
    assert max(sp) > 0.2, (name, sp)


def test_cnn_sparse_training_is_lossless():
    """Training under IN_OUT == training under DC, step for step — the
    system-level statement of the paper's exactness claim."""
    model = build_cnn("vgg16", image_size=8, width=0.125, num_classes=10)
    img, labels = image_batch(0, 0, batch=2, image_size=8, num_classes=10)

    def run(policy):
        params = model.init(jax.random.key(0))
        losses = []
        for step in range(3):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, img, labels, policy))(params)
            params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
            losses.append(float(loss))
        return losses

    dc = run(DC)
    sp = run(IN_OUT.with_(kernel_impl="pallas", block=(16, 16, 16)))
    np.testing.assert_allclose(dc, sp, rtol=2e-4, atol=2e-5)
    assert dc[-1] < dc[0]                  # actually learning


def test_conv_specs_geometry():
    model = build_cnn("vgg16", image_size=224, width=1.0, num_classes=1000)
    specs = model.conv_specs(batch=16)
    assert len(specs) == 13                # VGG16 conv layers
    assert specs[0].c == 3 and specs[0].m == 64
    # pool boundaries disable output sparsity for the next conv
    relu_flags = [s.input_is_relu for s in specs]
    assert relu_flags[0] is False          # raw image input
    assert relu_flags[2] is False          # post-pool
    assert relu_flags[1] is True


def test_serving_engine_continuous_batching():
    from repro.configs import SMOKE_ARCHS
    from repro.models.transformer import lm_init
    from repro.serving.engine import GenRequest, ServeEngine
    cfg = SMOKE_ARCHS["smollm-360m"]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    for rid in range(5):                   # more requests than slots
        eng.submit(GenRequest(rid, [1 + rid, 2, 3], max_tokens=4))
    done = eng.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(v) == 4 for v in done.values())


def test_serving_greedy_matches_manual_decode():
    from repro.configs import SMOKE_ARCHS
    from repro.models.transformer import decode_step, init_caches, lm_init
    from repro.serving.engine import GenRequest, ServeEngine
    cfg = SMOKE_ARCHS["stablelm-1.6b"]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    prompt = [5, 9, 2]
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    eng.submit(GenRequest(0, prompt, max_tokens=3))
    got = eng.run()[0]
    # manual single-stream decode
    caches = init_caches(cfg, 1, 32, jnp.float32)
    toks = list(prompt)
    out = []
    for i in range(len(prompt) + 2):
        feed = toks[i] if i < len(prompt) else out[-1]
        logits, caches = decode_step(params, jnp.asarray([feed], jnp.int32),
                                     caches, jnp.asarray(i, jnp.int32), cfg)
        if i >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
    assert got == out[:3]
