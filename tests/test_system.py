"""End-to-end behaviour tests for the paper's system."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.configs.base import TrainConfig
from repro.core.policy import DC, IN_OUT_WR
from repro.data.pipeline import image_batch
from repro.launch.train import train_loop
from repro.models.cnn import build_cnn

REPO = os.path.join(os.path.dirname(__file__), "..")


def test_lm_training_learns():
    """examples-style LM training descends on the synthetic stream."""
    cfg = SMOKE_ARCHS["smollm-360m"]
    tcfg = TrainConfig(total_steps=90, learning_rate=5e-3, warmup_steps=5)
    out = train_loop(cfg, tcfg, batch_size=8, seq_len=32, steps=90,
                     log_every=0)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.3, (first, last)


def test_cnn_training_with_paper_technique_end_to_end():
    """Sparse-backprop CNN training: learns, and the trace-driven cost
    model reports a BP speedup for the run's own sparsity."""
    from repro.core import costmodel as cm
    from repro.core.sparsity import element_sparsity
    model = build_cnn("vgg16", image_size=8, width=0.25, num_classes=10)
    params = model.init(jax.random.key(0))
    policy = IN_OUT_WR.with_(kernel_impl="xla_ref")
    # Fixed batch (memorization smoke): the tiny reduced-geometry model has
    # ~1e-3 gradients, so a fresh batch per step just random-walks the loss
    # around ln(10) — descent is only a deterministic property of repeated
    # steps on one batch.
    img, labels = image_batch(0, 0, batch=4, image_size=8, num_classes=10)
    losses = []
    for step in range(5):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, img, labels, policy))(params)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
        losses.append(float(loss))
    assert losses[-1] < losses[0]

    cap = {}
    model.apply(params, img, capture=cap)
    specs = model.conv_specs(batch=4)
    traces = []
    for s in specs:
        act = cap.get(s.name)
        dens = 1.0 - float(element_sparsity(act)) if act is not None else 1.0
        traces.append(cm.LayerTrace(x_density=dens, g_in_density=dens,
                                    out_mask_density=dens))
    dc = cm.network_cost(specs, traces, "DC")
    sp = cm.network_cost(specs, traces, "IN_OUT_WR")
    assert sp["bp_cycles"] < dc["bp_cycles"]
    assert sp["total_cycles"] < dc["total_cycles"]


@pytest.mark.slow
def test_dryrun_smoke_cell_subprocess():
    """The 512-device dry-run machinery works end-to-end (subprocess so the
    forced device count never leaks into this test session)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "stablelm-1.6b", "--shape", "train_4k", "--mesh", "multi",
         "--smoke", "--out", "/tmp/test_dryrun_cell.jsonl"],
        env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "[ok]" in out.stdout


def test_grad_compression_training_parity():
    """int8 EF compression barely perturbs a short optimization run."""
    from repro.optim.compression import init_error_state, quantize, dequantize
    from repro.optim.optimizer import OptConfig, adamw_init, adamw_update
    cfg = OptConfig(learning_rate=0.05, warmup_steps=0, total_steps=100,
                    weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(0).standard_normal(16),
                         jnp.float32)

    def run(compressed):
        params = {"w": jnp.zeros(16)}
        state = adamw_init(params)
        err = init_error_state(params)
        for _ in range(60):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            if compressed:
                q, scale, err["w"] = quantize(g["w"], err["w"])
                g = {"w": dequantize(q, scale)}
            params, state, _ = adamw_update(g, state, params, cfg)
        return float(jnp.sum((params["w"] - target) ** 2))

    exact, comp = run(False), run(True)
    assert comp < exact + 0.05
