"""The paper's central claim, as an executable property: sparse backprop is
EXACT — custom-VJP (with output/input skipping) == dense autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.core.sparse_conv import conv as sconv, relu_conv
from repro.core.sparse_linear import act_matmul, matmul as smm, relu_matmul

POLICIES = [
    pol.DC,
    pol.IN.with_(kernel_impl="pallas", block=(16, 16, 16)),
    pol.IN_OUT.with_(kernel_impl="pallas", block=(16, 16, 16)),
    pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 16, 8)),
    pol.IN_OUT,  # xla_ref
]


def _rand(shape, key, sparsify=0.0):
    rng = np.random.default_rng(key)
    x = rng.standard_normal(shape).astype(np.float32)
    if sparsify:
        x *= rng.random(shape) > sparsify
    return jnp.asarray(x)


@pytest.mark.parametrize("policy", POLICIES)
def test_relu_matmul_vjp_exact(policy):
    x = _rand((37, 29), 0)
    w = _rand((29, 23), 1)
    ct = _rand((37, 23), 2)
    y, vjp = jax.vjp(lambda x, w: relu_matmul(x, w, policy), x, w)
    yd, vjpd = jax.vjp(lambda x, w: jnp.maximum(x, 0) @ w, x, w)
    np.testing.assert_allclose(y, yd, rtol=1e-4, atol=1e-4)
    for g, gd in zip(vjp(ct), vjpd(ct)):
        np.testing.assert_allclose(g, gd, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("policy", [pol.DC, pol.IN_OUT.with_(
    kernel_impl="pallas", block=(16, 16, 16))])
def test_relu2_matmul_vjp_exact(policy):
    """Squared-ReLU (transformer-FFN variant): same zero footprint."""
    x = _rand((24, 18), 3)
    w = _rand((18, 20), 4)
    ct = _rand((24, 20), 5)
    f = lambda x, w: act_matmul(x, w, policy, "relu2")
    g = lambda x, w: jnp.square(jnp.maximum(x, 0)) @ w
    y, vjp = jax.vjp(f, x, w)
    yd, vjpd = jax.vjp(g, x, w)
    np.testing.assert_allclose(y, yd, rtol=1e-4, atol=1e-4)
    for a, b in zip(vjp(ct), vjpd(ct)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID"), (2, "VALID")])
def test_relu_conv_vjp_exact(stride, padding):
    policy = pol.IN_OUT.with_(kernel_impl="pallas", block=(16, 16, 16))
    x = _rand((2, 9, 11, 5), 6)
    w = _rand((3, 3, 5, 7), 7)

    def dense(x, w):
        return jax.lax.conv_general_dilated(
            jnp.maximum(x, 0), w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    f = lambda x, w: (relu_conv(x, w, stride, padding, policy) ** 2).sum()
    g = lambda x, w: (dense(x, w) ** 2).sum()
    np.testing.assert_allclose(f(x, w), g(x, w), rtol=1e-4)
    ga, gb = jax.grad(f, (0, 1))(x, w), jax.grad(g, (0, 1))(x, w)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


def test_bn_between_conv_and_relu_keeps_output_sparsity_exact():
    """The paper's headline case (Fig. 3c): BN after the conv — input
    sparsity is gone but output-sparse backprop is still exact."""
    policy = pol.IN_OUT.with_(kernel_impl="pallas", block=(8, 8, 8))
    x = _rand((2, 8, 8, 4), 8)
    w = _rand((3, 3, 4, 6), 9)
    scale = jnp.ones((6,))
    bias = jnp.zeros((6,))

    def bn(y):
        mu = y.mean(axis=(0, 1, 2), keepdims=True)
        var = y.var(axis=(0, 1, 2), keepdims=True)
        return (y - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias

    def net_sparse(x, w, w2):
        h = sconv(x, w, 1, "SAME", policy)       # conv (input not relu'd)
        h = bn(h)
        # h is now the PRE-activation consumed by the fused relu-conv
        return (relu_conv(h, w2, 1, "SAME", policy) ** 2).sum()

    def net_dense(x, w, w2):
        h = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = bn(h)
        h = jnp.maximum(h, 0)
        y = jax.lax.conv_general_dilated(
            h, w2, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return (y ** 2).sum()

    w2 = _rand((3, 3, 6, 5), 10)
    np.testing.assert_allclose(net_sparse(x, w, w2), net_dense(x, w, w2),
                               rtol=1e-4)
    gs = jax.grad(net_sparse, (0, 1, 2))(x, w, w2)
    gd = jax.grad(net_dense, (0, 1, 2))(x, w, w2)
    for a, b in zip(gs, gd):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


def test_gradients_flow_through_chain_of_units():
    """Three stacked relu_matmul units (the CONV-ReLU-CONV chain of Fig. 5)."""
    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    sizes = [12, 16, 16, 8]
    ws = [_rand((sizes[i], sizes[i + 1]), 20 + i) for i in range(3)]
    x = _rand((10, 12), 30)

    def net(ws, impl):
        h = x @ ws[0]
        for w in ws[1:]:
            h = impl(h, w)
        return (h ** 2).sum()

    f = lambda ws: net(ws, lambda h, w: relu_matmul(h, w, policy))
    g = lambda ws: net(ws, lambda h, w: jnp.maximum(h, 0) @ w)
    np.testing.assert_allclose(f(ws), g(ws), rtol=1e-4)
    for a, b in zip(jax.grad(f)(ws), jax.grad(g)(ws)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)
