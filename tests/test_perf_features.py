"""Tests for the §Perf levers: device-limited routing, bf16 Adam moments,
pure-DP analytic accounting, stash-sharding config plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import TRAIN_4K
from repro.launch.flops import analytic_cost
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.optim.optimizer import OptConfig, adamw_init, adamw_update


def test_device_limited_routing_restricts_groups():
    cfg = MoEConfig(n_experts=16, top_k=4, d_ff_expert=32,
                    device_groups=4, top_groups=2, capacity_factor=8.0)
    params = moe_init(jax.random.key(0), 16, cfg)
    x = jax.random.normal(jax.random.key(1), (32, 16))
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()
    # verify the selected experts span ≤ top_groups groups per token
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    pg = probs.reshape(32, 4, 4)
    gscore = pg.max(-1)
    _, gidx = jax.lax.top_k(gscore, 2)
    gmask = jax.nn.one_hot(gidx, 4).sum(1)
    masked = (pg * gmask[..., None]).reshape(32, 16)
    _, eidx = jax.lax.top_k(masked, 4)
    groups_used = np.asarray(eidx // 4)
    for row, allowed in zip(groups_used, np.asarray(gidx)):
        assert set(row).issubset(set(allowed)), (row, allowed)


def test_device_limited_routing_halves_a2a_model():
    base = ARCHS["deepseek-v2-lite-16b"]
    lim = base.with_(moe=dataclasses.replace(
        base.moe, device_groups=16, top_groups=3))
    a = analytic_cost(base, TRAIN_4K, dp_n=16, model_n=16)
    b = analytic_cost(lim, TRAIN_4K, dp_n=16, model_n=16)
    r = b.detail["coll_ep_a2a"] / a.detail["coll_ep_a2a"]
    assert abs(r - 0.5) < 1e-6


def test_bf16_moments_halve_state_and_still_converge():
    params = {"w": jnp.zeros(3)}
    s32 = adamw_init(params)
    s16 = adamw_init(params, moment_dtype=jnp.bfloat16)
    assert s16["mu"]["w"].dtype == jnp.bfloat16
    assert s16["master"]["w"].dtype == jnp.float32
    cfg = OptConfig(learning_rate=0.1, warmup_steps=5, total_steps=200,
                    weight_decay=0.0)
    target = jnp.asarray([1.0, -1.0, 0.5])
    state, p = s16, params
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
        p, state, _ = adamw_update(g, state, p, cfg)
    assert float(jnp.sum((p["w"] - target) ** 2)) < 1e-2


def test_pure_dp_accounting_kills_tp_collectives():
    cfg = ARCHS["smollm-360m"]
    tp = analytic_cost(cfg, TRAIN_4K, dp_n=16, model_n=16)
    dp = analytic_cost(cfg, TRAIN_4K, dp_n=256, model_n=1)
    assert "coll_tp" in tp.detail and tp.detail["coll_tp"] > 0
    assert "coll_tp" not in dp.detail
    assert dp.coll_bytes_per_device < 0.1 * tp.coll_bytes_per_device


def test_stash_sharding_rule_plumbing():
    """The act_stash constraint is a no-op without rules and valid with."""
    from repro.configs import SMOKE_ARCHS
    from repro.models.transformer import lm_init, lm_loss
    from repro.sharding import sharding_rules
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = SMOKE_ARCHS["stablelm-1.6b"]
    params = lm_init(jax.random.key(0), cfg, dtype=jnp.float32)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 17), 0,
                                          cfg.vocab_size)}
    base = float(lm_loss(params, batch, cfg))
    mesh = jax.make_mesh((1,), ("model",))
    with sharding_rules({"act_stash": NamedSharding(mesh, P())}):
        with_rule = float(lm_loss(params, batch, cfg))
    np.testing.assert_allclose(base, with_rule, rtol=1e-6)
