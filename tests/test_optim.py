"""Optimizer, loss scaling, and gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import (compressed_psum, dequantize,
                                     init_error_state, quantize)
from repro.optim.optimizer import OptConfig, adamw_init, adamw_update, cosine_lr


def test_adamw_converges_quadratic():
    cfg = OptConfig(learning_rate=0.1, warmup_steps=5, total_steps=200,
                    weight_decay=0.0, grad_clip=10.0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss_fn(params)) < 1e-3


def test_nonfinite_grad_skips_update():
    cfg = OptConfig()
    params = {"w": jnp.ones(4)}
    state = adamw_init(params)
    bad = {"w": jnp.full(4, jnp.nan)}
    p2, s2, m = adamw_update(bad, state, params, cfg)
    np.testing.assert_array_equal(p2["w"], params["w"])
    assert int(s2["step"]) == 0
    assert float(m["skipped"]) == 1.0


def test_loss_scale_unscales():
    cfg = OptConfig(loss_scale=1024.0, weight_decay=0.0)
    params = {"w": jnp.ones(2)}
    s0 = adamw_init(params)
    g_scaled = {"w": jnp.asarray([1024.0, 2048.0])}
    _, _, m1 = adamw_update(g_scaled, s0, params, cfg)
    cfg2 = OptConfig(loss_scale=0.0, weight_decay=0.0)
    _, _, m2 = adamw_update({"w": jnp.asarray([1.0, 2.0])},
                            adamw_init(params), params, cfg2)
    np.testing.assert_allclose(m1["grad_norm"], m2["grad_norm"], rtol=1e-6)


def test_cosine_schedule_shape():
    cfg = OptConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(cosine_lr(jnp.asarray(0), cfg)) == 0.0
    assert abs(float(cosine_lr(jnp.asarray(10), cfg)) - 1.0) < 1e-6
    assert float(cosine_lr(jnp.asarray(100), cfg)) <= 0.1 + 1e-6


def test_master_weights_preserve_precision():
    """bf16 params with f32 master: tiny updates are not lost."""
    cfg = OptConfig(learning_rate=1e-4, weight_decay=0.0, warmup_steps=0,
                    total_steps=10_000, min_lr_frac=1.0)
    params = {"w": jnp.full((4,), 256.0, jnp.bfloat16)}   # ulp = 1.0 at 256
    state = adamw_init(params)
    for _ in range(50):
        g = {"w": jnp.full((4,), 1.0, jnp.bfloat16)}
        params, state, _ = adamw_update(g, state, params, cfg)
    # master moved by ~50·1e-4 even though each step < bf16 ulp
    assert float(state["master"]["w"][0]) < 256.0 - 3e-3


def test_compressed_psum_shard_map():
    """Mechanics of the int8 EF all-reduce under shard_map (axis size 1 on
    CPU; numerics of quantize path still exercised end-to-end)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = {"w": jnp.asarray([0.1, -0.5, 0.25, 3.0])}
    err = init_error_state(g)

    def f(g, err):
        return compressed_psum(g, err, "data")

    out, err2 = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                          out_specs=(P(), P()))(g, err)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    np.testing.assert_allclose(out["w"], g["w"], atol=scale + 1e-7)
    # error feedback holds the residual
    np.testing.assert_allclose(np.asarray(err2["w"]),
                               np.asarray(g["w"] - out["w"]), atol=1e-6)


def test_compression_roundtrip_precision():
    g = jnp.linspace(-1, 1, 255)
    q, scale, err = quantize(g, jnp.zeros_like(g))
    back = dequantize(q, scale)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) / 2 + 1e-7
