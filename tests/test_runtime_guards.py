"""Guarded execution (docs/resilience.md): the StepGuard verdict ladder,
the autotune degradation/quarantine machinery, the fault-injection sites,
and host-state persistence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.kernels import autotune, stats
from repro.kernels.ops import GemmMasks, sparse_gemm
from repro.runtime import faults
from repro.runtime.guards import (GuardConfig, StepGuard, VERDICTS,
                                  reference_bitmap)


# ---------------------------------------------------------------------------
# StepGuard: the verdict state machine
# ---------------------------------------------------------------------------

def test_healthy_steps_stay_ok():
    g = StepGuard()
    for s in range(5):
        assert g.observe_step(s, loss=1.0, grad_norm=0.5, skipped=0.0) == "ok"
    assert stats.guard_counts()["guard:verdict:ok"] == 5


@pytest.mark.parametrize("bad", [
    dict(loss=float("nan")), dict(loss=float("inf")),
    dict(grad_norm=float("nan")), dict(skipped=1.0)])
def test_any_nonfinite_signal_is_a_skip(bad):
    g = StepGuard()
    assert g.observe_step(0, **{"loss": 1.0, "grad_norm": 1.0,
                                "skipped": 0.0, **bad}) == "skip"


def test_skip_budget_escalates_to_rollback_then_degrade():
    g = StepGuard(GuardConfig(max_consecutive_skips=2, max_rollbacks=1,
                              rollback_backoff=4))
    seq = [g.observe_step(s, loss=float("nan")) for s in range(7)]
    # 2 skips → rollback; budget restarts; 2 skips → degrade (rollback
    # quota exhausted while still hot)
    assert seq == ["skip", "skip", "rollback", "skip", "skip",
                   "degrade", "skip"]
    gc = stats.guard_counts()
    assert gc["guard:verdict:rollback"] == 1
    assert gc["guard:verdict:degrade"] == 1


def test_clean_cooldown_forgets_rollbacks():
    cfg = GuardConfig(max_consecutive_skips=1, max_rollbacks=1,
                      rollback_backoff=2)
    g = StepGuard(cfg)
    assert g.observe_step(0, loss=float("nan")) == "skip"
    assert g.observe_step(1, loss=float("nan")) == "rollback"
    # backoff = 2 clean steps; after them the rollback counter cools, so
    # the NEXT escalation is a rollback again, not a degrade
    assert g.observe_step(2, loss=1.0) == "ok"
    assert g.observe_step(3, loss=1.0) == "ok"
    assert g.observe_step(4, loss=float("nan")) == "skip"
    assert g.observe_step(5, loss=float("nan")) == "rollback"


def test_guard_state_roundtrip():
    g = StepGuard(GuardConfig(max_consecutive_skips=3))
    for s, loss in enumerate([1.0, float("nan"), float("nan")]):
        g.observe_step(s, loss=loss)
    doc = g.export_state()
    g2 = StepGuard(GuardConfig(max_consecutive_skips=3))
    g2.import_state(doc)
    # the resumed guard continues the SAME ladder: one more non-finite
    # step exhausts the budget it inherited
    assert g2.observe_step(3, loss=float("nan")) == "skip"
    assert g2.observe_step(4, loss=float("nan")) == "rollback"
    assert g2.verdicts[:3] == [(0, "ok"), (1, "skip"), (2, "skip")]


def test_scan_counters_detects_registry_miss_storm():
    g = StepGuard()
    g.scan_counters()
    for _ in range(3):
        stats.record("registry:miss")
    d = g.scan_counters(expected_registry_misses=1)
    assert d["registry:miss"] == 3
    assert stats.guard_counts().get("guard:registry_miss", 0) == 1
    # structural misses alone don't trip it
    stats.record("registry:miss")
    g.scan_counters(expected_registry_misses=1)
    assert stats.guard_counts().get("guard:registry_miss", 0) == 1


# ---------------------------------------------------------------------------
# Bitmap consistency probe
# ---------------------------------------------------------------------------

def test_probe_emit_accepts_consistent_pairs():
    out = np.zeros((8, 8), np.float32)
    out[0, 0] = 1.0
    bits = reference_bitmap(out, (4, 4))
    ok, corrected = StepGuard().probe_emit(out, bits, (4, 4))
    assert ok
    np.testing.assert_array_equal(np.asarray(corrected), bits)
    assert "guard:bitmap_mismatch" not in stats.guard_counts()


def test_probe_emit_flags_and_corrects_flips():
    out = np.zeros((8, 12), np.float32)
    out[5, 9] = 2.0
    bits = reference_bitmap(out, (4, 4))
    flipped = bits.copy()
    flipped[0, 0] ^= 1
    ok, corrected = StepGuard().probe_emit(out, flipped, (4, 4))
    assert not ok
    np.testing.assert_array_equal(np.asarray(corrected), bits)
    assert stats.guard_counts()["guard:bitmap_mismatch"] == 1


def test_reference_bitmap_matches_emitted_bitmap():
    """The probe's oracle agrees with the kernel's emitted bitmap on a
    clean run — otherwise every probe would be a false positive."""
    rng = np.random.default_rng(0)
    a = (rng.standard_normal((16, 12)) *
         (rng.random((16, 12)) > 0.7)).astype(np.float32)
    b = rng.standard_normal((12, 16)).astype(np.float32)
    P = pol.IN_OUT.with_(kernel_impl="pallas", block=(8, 8, 8))
    spec = P.gemm_spec(dims=(16, 12, 16)).with_(
        epilogue=("bitmap_emit",), emit_gran=(4, 4))
    out, bits = sparse_gemm(a, b, None, spec=spec)
    ok, _ = StepGuard().probe_emit(out, bits, (4, 4))
    assert ok


# ---------------------------------------------------------------------------
# Degradation ladder / quarantine (kernels/autotune.py)
# ---------------------------------------------------------------------------

def test_clamp_schedule_ladder():
    assert autotune.clamp_schedule("compact", 0) == "compact"
    assert autotune.clamp_schedule("compact", 1) == "predicated"
    assert autotune.clamp_schedule("compact", 2) == "dense"
    assert autotune.clamp_schedule("predicated", 1) == "predicated"
    assert autotune.clamp_schedule("dense", 2) == "dense"


def test_demote_emits_schema_compatible_log_rows():
    """Demotion events ride the SAME decision-log row schema the audit
    table and the wall-clock schema gate assert on (reason in the event
    string, no new fields)."""
    P = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    spec = P.gemm_spec(dims=(16, 16, 16))
    cache = autotune.get_cache()
    key = cache.report_suspect(spec, (16, 16, 16), "bitmap")
    assert cache.demote(key, reason="guard") == "predicated"
    rows = autotune.log_rows()
    assert rows and rows[-1]["event"] == "demote:guard"
    expected = {"seq", "event", "key", "shape", "groups", "schedule",
                "block", "live_frac", "operand_frac", "samples"}
    assert set(rows[-1]) == expected
    assert stats.guard_counts()["guard:demote"] == 1


def test_quarantine_clamps_static_resolution():
    """A demoted key stays demoted on the NON-autotuned resolution path:
    policy.gemm_spec must not hand back the compact schedule."""
    P = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    dims = (16, 16, 16)
    assert P.gemm_spec(dims=dims).schedule == "compact"
    cache = autotune.get_cache()
    cache.demote(autotune.key_for(P.gemm_spec(dims=dims), dims),
                 reason="test")
    clamped = P.gemm_spec(dims=dims)
    assert clamped.schedule == "predicated"
    assert stats.guard_counts()["guard:quarantine_clamp"] == 1
    # one more rung: dense only
    cache.demote(autotune.key_for(clamped, dims), reason="test")
    assert P.gemm_spec(dims=dims).schedule == "dense"


def test_shapeless_twin_demotion_covers_all_shapes():
    """Demoting a spec's shapeless key demotes every shaped resolution of
    that spec (the spec misbehaves, not one shape of it)."""
    P = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    spec = P.gemm_spec(dims=(16, 16, 16))
    shapeless = autotune.key_for(spec, None)
    autotune.get_cache().demote(shapeless, reason="test")
    assert P.gemm_spec(dims=(16, 16, 16)).schedule == "predicated"
    assert P.gemm_spec(dims=(32, 16, 8)).schedule == "predicated"


def test_persistent_overflow_autodemotes_with_log_event():
    """The acceptance criterion: a spec whose compact queue persistently
    overflows is demoted off the compact schedule, with a
    ``demote:overflow`` event in the decision log."""
    autotune.reset(overflow_demote_after=3)
    rng = np.random.default_rng(1)
    a = rng.standard_normal((16, 16)).astype(np.float32)
    b = rng.standard_normal((16, 16)).astype(np.float32)
    mask = np.array([[1, 1], [1, 1]], dtype=np.int32)
    P = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    dims = (16, 16, 16)
    ref = a @ b
    faults.arm(faults.Fault("gemm:spec", "queue_overflow"))
    try:
        for _ in range(4):
            spec = P.gemm_spec(dims=dims)
            out = sparse_gemm(a, b, GemmMasks(out=mask), spec=spec)
            np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4)
    finally:
        faults.disarm()
    c = stats.counts()
    assert c["fallback:queue_overflow"] >= 3     # counted every overflow
    demotes = [r for r in autotune.log_rows()
               if r["event"] == "demote:overflow"]
    assert len(demotes) == 1
    assert P.gemm_spec(dims=dims).schedule == "predicated"


def test_autotune_state_roundtrip_preserves_quarantine():
    P = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    dims = (16, 16, 16)
    cache = autotune.get_cache()
    cache.demote(autotune.key_for(P.gemm_spec(dims=dims), dims),
                 reason="test")
    doc = autotune.export_state()
    autotune.reset()
    assert P.gemm_spec(dims=dims).schedule == "compact"   # fresh cache
    autotune.import_state(doc)
    assert P.gemm_spec(dims=dims).schedule == "predicated"
    rows = autotune.log_rows()
    assert any(r["event"] == "demote:test" for r in rows)


# ---------------------------------------------------------------------------
# Fault-injection harness
# ---------------------------------------------------------------------------

def test_unknown_site_and_kind_rejected():
    with pytest.raises(ValueError):
        faults.arm(faults.Fault("nonsense:site", "nan"))
    with pytest.raises(ValueError):
        faults.arm(faults.Fault("gemm:spec", "nan"))


def test_faults_are_deterministic_and_step_gated():
    f = faults.arm(faults.Fault("train:params", "nan", step=3, seed=5))
    try:
        tree = {"w": jnp.ones((4, 4)), "b": jnp.ones((3,))}
        same = faults.tap("train:params", tree, step=1)
        assert same is tree and f.fired == 0          # wrong step: no-op
        out1 = faults.tap("train:params", tree, step=3)
        out2 = faults.tap("train:params", tree, step=3)
        assert f.fired == 2
        for l1, l2 in zip(jax.tree_util.tree_leaves(out1),
                          jax.tree_util.tree_leaves(out2)):
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        assert any(bool(jnp.isnan(l).any())
                   for l in jax.tree_util.tree_leaves(out1))
    finally:
        faults.disarm()


def test_disarm_restores_hooks():
    from repro.core import sparse_tensor
    from repro.kernels import ops
    faults.arm(faults.Fault("gemm:spec", "queue_overflow"))
    assert ops._TAMPER_HOOK is not None
    assert sparse_tensor._REGISTER_HOOK is not None
    faults.disarm()
    assert ops._TAMPER_HOOK is None
    assert sparse_tensor._REGISTER_HOOK is None


def test_chaos_matrix_eager_cases_green():
    """The eager slice of the chaos matrix (no training loops — those run
    in the CI chaos job) must be green: every fault detected, attributed
    and survived."""
    rows = faults.run_matrix(["bitmap", "queue", "registry", "ckpt"])
    assert len(rows) == 5
    for r in rows:
        assert r.detected, (r.fault, r.detail)
        assert r.survived, (r.fault, r.detail)
        assert r.guard_key


def test_matrix_csv_written(tmp_path):
    rows = faults.run_matrix(["ckpt_crash"])
    p = tmp_path / "chaos.csv"
    faults.write_csv(rows, str(p))
    text = p.read_text().splitlines()
    assert text[0].startswith("fault,site,kind,detected")
    assert len(text) == 1 + len(rows)
