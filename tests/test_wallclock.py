"""Wall-clock truth: the autotuner's decision loop, the BENCH_7 schema,
and regression cells for the three timing bugs this PR fixed —

  (a) benchmarks/run.py printed ``us_per_call`` for a whole-table time and
      crashed persisting heterogeneous rows,
  (b) launch/train.py synced device→host EVERY step via
      ``float(metrics["loss"])``,
  (c) StragglerDetector judged each step against a median that INCLUDED
      the step itself and was seeded with the compile step.
"""
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import policy as pol
from repro.kernels import autotune, ops, stats
from repro.kernels.shapes import block_bitmap

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _pallas_wr(**kw):
    return pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8), **kw)


# ---------------------------------------------------------------------------
# measure(): compile excluded, fenced, median-of-k
# ---------------------------------------------------------------------------

def test_measure_excludes_compile_and_reports_median():
    from benchmarks.wallclock import measure
    calls = {"n": 0}

    def fake_compile_then_fast():
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.05)               # the "compile" call
        return jnp.zeros(())

    out = measure(fake_compile_then_fast, warmup=1, reps=5)
    assert calls["n"] == 6                 # warmup + reps, nothing more
    assert set(out) == {"us_median", "us_iqr", "reps", "warmup"}
    # a harness that timed the first call would report >= 50ms here
    assert 0 < out["us_median"] < 25_000
    assert out["us_iqr"] >= 0


# ---------------------------------------------------------------------------
# BENCH_7.json: committed artifact validates; mutations are drift
# ---------------------------------------------------------------------------

def _bench_doc():
    path = os.path.join(REPO_ROOT, "BENCH_7.json")
    with open(path) as f:
        return json.load(f)


def test_committed_bench_passes_schema():
    from benchmarks.wallclock import SCHEDULES, check_schema
    doc = _bench_doc()
    assert check_schema(doc) == []
    # acceptance coverage, asserted directly: every schedule measured for
    # >= 1 CNN and >= 1 FFN GEMM workload, compile-excluded and fenced
    for fam in ("cnn", "ffn"):
        got = {r["schedule"] for r in doc["rows"]
               if r["table"] == "gemm" and r["workload"].startswith(fam)}
        assert got == set(SCHEDULES), (fam, got)
    assert {r["workload"].split(":")[0] for r in doc["rows"]
            if r["table"] == "train_step"} == {"cnn", "ffn"}


@pytest.mark.parametrize("mutate,frag", [
    (lambda d: d.pop("autotune"), "missing top-level"),
    (lambda d: d["rows"][0].pop("us_median"), "key drift"),
    (lambda d: d["rows"][0].update(extra=1), "key drift"),
    (lambda d: d["rows"].__setitem__(
        slice(None), [r for r in d["rows"]
                      if not (r["table"] == "gemm"
                              and r["schedule"] == "compact")]),
     "missing schedules"),
    (lambda d: d["autotune"].update(log=[]), "not traceable"),
])
def test_schema_mutations_are_drift(mutate, frag):
    from benchmarks.wallclock import check_schema
    doc = _bench_doc()
    mutate(doc)
    errs = check_schema(doc)
    assert errs and any(frag in e for e in errs), (frag, errs)


# ---------------------------------------------------------------------------
# BENCH_8.json: fused emit beats GEMM-then-scan, committed and gated
# ---------------------------------------------------------------------------

def _bench8_doc():
    path = os.path.join(REPO_ROOT, "BENCH_8.json")
    with open(path) as f:
        return json.load(f)


def test_committed_bench8_passes_schema_and_claim():
    from benchmarks.wallclock import (
        EMIT_SCHEDULES, EMIT_VARIANTS, check_emit_schema,
    )
    doc = _bench8_doc()
    assert doc["geometry"] == "full"     # committed artifact carries claim
    assert check_emit_schema(doc) == []
    # acceptance, asserted directly: per (workload, schedule) the fused
    # σ′+emit launch is strictly faster than GEMM-then-bitmap_scan, for
    # both pallas schedules on a CNN and an FFN backward-dX workload
    cells = {}
    for r in doc["rows"]:
        cells[(r["workload"], r["schedule"], r["variant"])] = r["us_median"]
    fams = {w.split(":", 1)[0] for w, _, _ in cells}
    assert fams == {"cnn", "ffn"}, fams
    for (w, s, v) in list(cells):
        assert s in EMIT_SCHEDULES and v in EMIT_VARIANTS
        if v == "fused":
            assert cells[(w, s, "fused")] < cells[(w, s, "gemm_scan")], (w, s)


@pytest.mark.parametrize("mutate,frag", [
    (lambda d: d.pop("rows"), "missing top-level"),
    (lambda d: d["rows"][0].pop("emit_gran"), "key drift"),
    (lambda d: d["rows"][0].update(extra=1), "key drift"),
    (lambda d: d["rows"].__setitem__(
        slice(None), [r for r in d["rows"] if r["variant"] != "gemm_scan"]),
     "missing cells"),
    (lambda d: next(r for r in d["rows"] if r["variant"] == "fused")
        .update(us_median=10 ** 9), "not faster"),
])
def test_bench8_mutations_are_drift(mutate, frag):
    from benchmarks.wallclock import check_emit_schema
    doc = _bench8_doc()
    mutate(doc)
    errs = check_emit_schema(doc)
    assert errs and any(frag in e for e in errs), (frag, errs)


def test_cnn_gemm_dims_come_from_the_model():
    from benchmarks.wallclock import cnn_gemm_dims
    name, (m, k, n) = cnn_gemm_dims(image_size=8, width=0.125, batch=2)
    assert name == "cnn:vgg16:conv2:bp_dx"
    # bp_dx of conv2 at this geometry: M = input pixels, K = Cout·R·S,
    # N = Cin — straight from CNNModel.gemm_workload, not invented.
    assert (m, k, n) == (2 * 8 * 8, 8 * 9, 8)


# ---------------------------------------------------------------------------
# Autotune cache: hit / miss / measured retune / drift flip
# ---------------------------------------------------------------------------

def test_cache_hit_miss_and_measured_flip():
    cache = autotune.AutotuneCache(window=4, min_samples=2)
    spec = _pallas_wr().gemm_spec()
    key = autotune.key_for(spec)

    assert cache.resolve(key, spec).schedule == "compact"   # static default
    assert (cache.misses, cache.hits) == (1, 0)
    assert cache.resolve(key, spec).schedule == "compact"   # cache hit
    assert (cache.misses, cache.hits) == (1, 1)

    for _ in range(3):
        cache.observe(key, 0.2)
    got = cache.resolve(key, spec)       # newly measured → explicit retune
    assert got.schedule == "compact" and cache.retunes == 1

    for _ in range(cache.window):        # synthetic drift: all-live window
        cache.observe(key, 1.0)
    assert cache.resolve(key, spec).schedule == "dense"
    assert cache.retunes == 2
    events = [r["event"] for r in cache.log]
    assert events.count("hit") >= 1 and events.count("retune") == 2


def test_per_shape_keys_hold_different_schedules():
    cache = autotune.AutotuneCache(window=4, min_samples=2)
    spec = _pallas_wr().gemm_spec()
    ka = autotune.key_for(spec, dims=(32, 16, 24))
    kb = autotune.key_for(spec, dims=(16, 16, 16))
    assert ka != kb and ka.padded == (32, 16, 24)
    for _ in range(3):
        cache.observe(ka, 0.2)
        cache.observe(kb, 1.0)
    assert cache.resolve(ka, spec, dims=(32, 16, 24)).schedule == "compact"
    assert cache.resolve(kb, spec, dims=(16, 16, 16)).schedule == "dense"


def test_key_ignores_schedule_epilogue_and_dtype():
    spec = _pallas_wr().gemm_spec()
    post = spec.with_(schedule="predicated", epilogue="sigma_prime",
                      out_dtype=jnp.bfloat16)
    # sparse_linear._mm applies with_() AFTER policy resolution; the key
    # must not split its observation stream from the resolution stream.
    assert autotune.key_for(spec) == autotune.key_for(post)


def test_block_refinement_needs_dims():
    cache = autotune.AutotuneCache(window=4, min_samples=2)
    spec = _pallas_wr().gemm_spec()
    key_nd = autotune.key_for(spec)
    for _ in range(3):
        cache.observe(key_nd, 0.8)       # mostly live, still masking
    # no dims (the linear funnel builds masks at the policy block): the
    # block must never move
    assert cache.resolve(key_nd, spec).block == (8, 8, 8)
    key_d = autotune.key_for(spec, dims=(32, 16, 24))
    for _ in range(3):
        cache.observe(key_d, 0.8)
    got = cache.resolve(key_d, spec, dims=(32, 16, 24), grans=(1, 1, 1))
    assert got.schedule == "predicated" and got.block == (4, 4, 4)


def test_autotune_flip_through_policy_resolution():
    """End to end through the sanctioned resolution point: eager dispatches
    with concrete masks drive the policy's resolved schedule from compact
    to dense, numerics staying exact throughout."""
    stats.reset()
    autotune.reset(window=4, min_samples=2)
    policy = _pallas_wr(autotune=True)
    m, k, n = 16, 8, 16
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    seen = []
    for live in [0.0] * 4 + [1.0] * 8:
        bm = jnp.full((m // 8, n // 8), bool(live)) if live in (0.0, 1.0) \
            else None
        spec = policy.gemm_spec()
        assert spec.origin == "policy"
        got = ops.sparse_gemm(a, b, ops.GemmMasks(out=bm), spec)
        seen.append(spec.schedule)
        expand = jnp.repeat(jnp.repeat(bm, 8, 0), 8, 1)
        np.testing.assert_allclose(got, (a @ b) * expand,
                                   rtol=1e-5, atol=1e-5)
    assert seen[0] == "compact"          # static default while unmeasured
    assert seen[-1] == "dense"           # measured all-live window
    assert autotune.get_cache().retunes >= 1
    assert autotune.log_rows()           # every selection traceable


# ---------------------------------------------------------------------------
# (a) run.py: honest header + union-of-keys CSV persistence
# ---------------------------------------------------------------------------

def test_run_header_is_us_total():
    from benchmarks.run import HEADER
    assert HEADER == "name,us_total,derived"


def test_write_rows_heterogeneous(tmp_path):
    """fieldnames=rows[0].keys() raised ValueError on any row with a key
    the first row lacked; union-of-keys + restval must take it."""
    from benchmarks.run import write_rows
    rows = [{"a": 1, "b": 2}, {"a": 3, "c": 4}]
    path = str(tmp_path / "t.csv")
    write_rows(path, rows)
    lines = open(path).read().splitlines()
    assert lines[0] == "a,b,c"           # union, first-seen order
    assert lines[1:] == ["1,2,", "3,,4"]


# ---------------------------------------------------------------------------
# (c) StragglerDetector: self-exclusion + compile skip
# ---------------------------------------------------------------------------

def test_straggler_median_excludes_current_sample():
    from repro.launch.train import StragglerDetector
    det = StragglerDetector(window=8, threshold=2.0, min_history=8,
                            skip_first=False)
    for i, dt in enumerate([1.0] * 4 + [3.0] * 4):
        assert not det.observe(i, dt)
    # trailing median excluding the candidate is 2.0 → 5.0 flags (5 > 4);
    # the old self-inclusive median was 3.0 → 5.0 excused itself (5 < 6).
    assert det.observe(8, 5.0)
    assert det.flags == [(8, 5.0, 2.0)]


def test_straggler_skips_compile_step():
    from repro.launch.train import StragglerDetector
    det = StragglerDetector(window=8, threshold=2.0, min_history=4)
    assert not det.observe(0, 50.0)      # compile+execute: not history
    assert det.times == []
    for i in range(1, 5):
        assert not det.observe(i, 0.1)
    assert det.observe(5, 0.3)           # 0.3 > 2 × median(0.1)
    assert 50.0 not in det.times         # the old seed poisoned the median


# ---------------------------------------------------------------------------
# (b) train_loop: loss stays on device until the loop ends
# ---------------------------------------------------------------------------

def test_train_loop_defers_loss_materialization(monkeypatch):
    from repro.configs import SMOKE_ARCHS
    from repro.configs.base import TrainConfig
    from repro.launch.train import train_loop

    steps_done = {"n": 0}
    float_at_step = []

    class LossProxy:
        def __init__(self, v):
            self.v = v

        def __float__(self):
            float_at_step.append(steps_done["n"])
            return float(self.v)

    real_jit = jax.jit

    def spy_jit(fn, **kw):
        jitted = real_jit(fn, **kw)
        if kw.get("donate_argnums") != (0, 1):
            return jitted                # only wrap the train-step jit

        def wrapped(*a):
            p, o, m = jitted(*a)
            steps_done["n"] += 1
            m = dict(m)
            m["loss"] = LossProxy(m["loss"])
            return p, o, m
        return wrapped

    monkeypatch.setattr(jax, "jit", spy_jit)
    steps = 3
    out = train_loop(SMOKE_ARCHS["smollm-360m"],
                     TrainConfig(total_steps=steps, learning_rate=1e-3),
                     batch_size=2, seq_len=8, steps=steps, ckpt_dir=None,
                     log_every=0)
    # Every float() of a loss happened AFTER the final step dispatched —
    # the old per-step float(metrics["loss"]) yields [1, 2, 3] here.
    assert float_at_step == [steps] * steps
    assert [isinstance(l, float) for l in out["losses"]] == [True] * steps


def test_train_loop_syncs_only_for_consumers():
    """With a metrics consumer the values it receives are host floats."""
    from repro.configs import SMOKE_ARCHS
    from repro.configs.base import TrainConfig
    from repro.launch.train import train_loop

    got = []
    train_loop(SMOKE_ARCHS["smollm-360m"],
               TrainConfig(total_steps=2, learning_rate=1e-3),
               batch_size=2, seq_len=8, steps=2, ckpt_dir=None,
               log_every=0, on_metrics=lambda s, m: got.append((s, m)))
    assert [s for s, _ in got] == [0, 1]
    for _, m in got:
        assert isinstance(m["loss"], float)
        assert {"time_s", "straggler"} <= set(m)
