"""The PR's contract: forward-pass bitmaps are computed ONCE and every
backward mask is *derived* from them — and the derivations are bit-identical
to freshly-computed dense scans (the ``_bitmap_padded`` oracle).

Three property families, as deterministic sweeps:
  1. threaded forward bitmap == dense-scan oracle, for act_matmul and
     relu_conv (stride ∈ {1, 2}, padding ∈ {SAME, VALID});
  2. gradients stay exact vs dense autodiff after the threading refactor
     (incl. the fused σ'-epilogue and its ablation);
  3. the bitmap-op counter: exactly one activation bitmap computation per
     unit per training step, and ZERO standalone gradient scans — dy
     bitmaps are emitted by the producing GEMM's ``bitmap_emit`` epilogue
     (counted ``emit:grad``), with ``scan_pallas:*`` identically zero on
     full CNN and FFN training steps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.core.sparse_conv import (
    _im2col, _pad_amounts, _patch_bitmap, _relu_conv_fwd, conv as sconv,
    relu_conv,
)
from repro.core.sparse_linear import (
    _act_matmul_fwd, _bitmap_padded, act_matmul, relu_matmul,
)
from repro.core.sparse_tensor import (
    SparseTensor, coarsen_bitmap, conv_channel_granularity,
    linear_act_granularity,
)
from repro.kernels import stats

PALLAS = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 16, 8))
PALLAS_U = pol.IN_OUT.with_(kernel_impl="pallas", block=(16, 16, 16))


def _rand(shape, key, sparsify=0.5):
    rng = np.random.default_rng(key)
    x = rng.standard_normal(shape).astype(np.float32)
    if sparsify:
        x *= rng.random(shape) > sparsify
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# 1. threaded bitmap == freshly-scanned oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [PALLAS, PALLAS_U])
def test_act_matmul_threaded_masks_match_oracle(policy):
    bm, bk, bn = policy.block
    x_pre = _rand((37, 29), 0)
    w = _rand((29, 23), 1, 0.0)
    _, (st, _) = _act_matmul_fwd(x_pre, w, policy, "relu")
    assert st.bitmap is not None
    x = jnp.maximum(x_pre, 0)
    # FP operand mask (bm, bk)
    np.testing.assert_array_equal(
        st.mask_for((bm, bk)), _bitmap_padded(x, bm, bk))
    # BP out_mask (bm, bn) over the σ' footprint == relu footprint
    mult = (x_pre > 0).astype(jnp.float32)
    np.testing.assert_array_equal(
        st.mask_for((bm, bn)), _bitmap_padded(mult, bm, bn))
    # WG transposed operand mask (bm, bk) over Xᵀ
    np.testing.assert_array_equal(
        st.t_mask_for((bm, bk)), _bitmap_padded(x.T, bm, bk))


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID"), (2, "VALID")])
@pytest.mark.parametrize("policy", [PALLAS, PALLAS_U])
def test_relu_conv_threaded_masks_match_oracle(stride, padding, policy):
    bm, bk, bn = policy.block
    n, h, wd, c = 2, 9, 11, 5
    x_pre = _rand((n, h, wd, c), 2)
    w = _rand((3, 3, c, 7), 3, 0.0)
    _, (st, _) = _relu_conv_fwd(x_pre, w, stride, padding, policy)
    assert st.bitmap is not None
    x = jnp.maximum(x_pre, 0)
    # out_mask over the (N·H·W, C) σ' footprint
    mask2d = (x_pre > 0).reshape(n * h * wd, c).astype(jnp.float32)
    np.testing.assert_array_equal(
        st.mask_for((bm, bn)), _bitmap_padded(mask2d, bm, bn))
    # patch (im2col) masks vs a fresh scan of the actual patch matrix
    plh = _pad_amounts(h, 3, stride, padding)
    plw = _pad_amounts(wd, 3, stride, padding)
    pad4 = (plh[0], plh[1], plw[0], plw[1])
    pm = _im2col(x, 3, 3, stride, pad4)
    pm = pm.reshape(-1, 3 * 3 * c)
    pb = _patch_bitmap(st, (n, h, wd, c), 3, 3, stride, pad4)
    np.testing.assert_array_equal(
        pb.mask_for((bm, bk)), _bitmap_padded(pm, bm, bk))
    np.testing.assert_array_equal(
        pb.t_mask_for((bm, bk)), _bitmap_padded(pm.T, bm, bk))


def test_coarsen_bitmap_is_exact_or_reduce():
    rng = np.random.default_rng(7)
    x = jnp.asarray((rng.random((40, 24)) > 0.8).astype(np.float32))
    fine = _bitmap_padded(x, 2, 4)           # (20, 6) at gran (2, 4)
    np.testing.assert_array_equal(
        coarsen_bitmap(fine, (2, 4), (8, 8)), _bitmap_padded(x, 8, 8))
    # ragged edges: coarsen pads fine bitmap with zeros, oracle pads data
    np.testing.assert_array_equal(
        coarsen_bitmap(fine, (2, 4), (16, 16)), _bitmap_padded(x, 16, 16))


# ---------------------------------------------------------------------------
# 2. gradients stay exact vs dense autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [
    PALLAS,                                  # compact × fused σ′ epilogue
    PALLAS_U,                                # predicated × fused epilogue
    PALLAS_U.with_(fuse_epilogue=False),     # ablation: separate VPU pass
    PALLAS.with_(fuse_epilogue=False),       # compact × separate VPU pass
    PALLAS.with_(queue_builder="argsort"),   # compact × fused, sort-built q
    pol.IN_OUT,                              # xla_ref threading path
])
def test_act_matmul_grads_exact_after_threading(policy):
    # x_pre continuous (no exact zeros): σ'(0)=0 vs dense-autodiff tie
    # handling is a convention choice, not a threading property.  Negatives
    # give ~50% activation sparsity for the masks to act on.
    x = _rand((37, 29), 10, 0.0)
    w = _rand((29, 23), 11, 0.0)
    ct = _rand((37, 23), 12, 0.7)
    y, vjp = jax.vjp(lambda x, w: relu_matmul(x, w, policy), x, w)
    yd, vjpd = jax.vjp(lambda x, w: jnp.maximum(x, 0) @ w, x, w)
    np.testing.assert_allclose(y, yd, rtol=1e-4, atol=1e-4)
    for g, gd in zip(vjp(ct), vjpd(ct)):
        np.testing.assert_allclose(g, gd, rtol=2e-4, atol=2e-4)
    # masked-out rows of dx are EXACT zeros (losslessness of the epilogue)
    dx = vjp(ct)[0]
    assert np.all(np.asarray(dx)[np.asarray(x) < 0] == 0.0)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID"), (2, "VALID")])
@pytest.mark.parametrize("policy", [PALLAS, PALLAS_U,
                                    PALLAS_U.with_(fuse_epilogue=False),
                                    PALLAS.with_(fuse_epilogue=False)])
def test_relu_conv_grads_exact_after_threading(stride, padding, policy):
    x = _rand((2, 9, 11, 5), 13, 0.0)     # continuous pre-activation
    w = _rand((3, 3, 5, 7), 14, 0.0)

    def dense(x, w):
        return jax.lax.conv_general_dilated(
            jnp.maximum(x, 0), w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    f = lambda x, w: (relu_conv(x, w, stride, padding, policy) ** 2).sum()
    g = lambda x, w: (dense(x, w) ** 2).sum()
    np.testing.assert_allclose(f(x, w), g(x, w), rtol=1e-4)
    ga, gb = jax.grad(f, (0, 1))(x, w), jax.grad(g, (0, 1))(x, w)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("queue_builder", ["prefix_sum", "argsort"])
def test_compact_epilogue_bounded_queue_grads_exact(queue_builder):
    """The compact×epilogue cell with a REAL queue bound: the fused σ′
    writeback must stay exact when the schedule is the compacted queue at
    exactly-live capacity (the WDU case) — for both queue builders."""
    from repro.kernels import ops as kops, ref as kref
    rng = np.random.default_rng(31)
    dy = jnp.asarray(rng.standard_normal((40, 24)), jnp.float32)
    w_t = jnp.asarray(rng.standard_normal((24, 48)), jnp.float32)
    relu_mask = jnp.asarray(rng.random((40, 48)) > 0.6, jnp.float32)
    mask_p = jnp.pad(relu_mask, ((0, 0), (0, 0)))
    n_live = int(np.asarray(kref.block_any_nonzero(mask_p, 8, 16)).sum())
    spec = kops.GemmSpec(block=(8, 8, 16), schedule="compact",
                         max_active_blocks=n_live,
                         queue_builder=queue_builder, epilogue="sigma_prime")
    got = kops.sparse_gemm(
        dy, w_t, kops.GemmMasks(out=kref.block_any_nonzero(mask_p, 8, 16)),
        spec, epilogue_mult=relu_mask)
    want = kref.relu_bwd_masked(dy, w_t, relu_mask, bm=8, bk=8, bn=16)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # fused-epilogue zeros are exact zeros even through the scatter-back
    assert np.all(np.asarray(got)[np.asarray(relu_mask) == 0] == 0.0)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "VALID")])
def test_plain_conv_grads_exact_after_threading(stride, padding):
    policy = PALLAS_U
    x = _rand((2, 8, 8, 4), 15, 0.0)         # signed input (post-pool case)
    w = _rand((3, 3, 4, 6), 16, 0.0)

    def dense(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    f = lambda x, w: (sconv(x, w, stride, padding, policy) ** 2).sum()
    g = lambda x, w: (dense(x, w) ** 2).sum()
    np.testing.assert_allclose(f(x, w), g(x, w), rtol=1e-4)
    for a, b in zip(jax.grad(f, (0, 1))(x, w), jax.grad(g, (0, 1))(x, w)):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# 3. the audit property: one bitmap computation per tensor per step
# ---------------------------------------------------------------------------

def _grad_eagerly(f, *args):
    return jax.grad(f, tuple(range(len(args))))(*args)


def test_act_matmul_one_bitmap_op_per_step():
    x = _rand((37, 29), 20)
    w = _rand((29, 23), 21, 0.0)
    stats.reset()
    _grad_eagerly(lambda x, w: (act_matmul(x, w, PALLAS, "relu") ** 2).sum(),
                  x, w)
    assert stats.total("act") == 1, stats.counts()   # fused fwd encode only
    assert stats.total("grad") == 1, stats.counts()  # one dy scan, 2 masks


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "VALID")])
def test_relu_conv_one_bitmap_op_per_step(stride, padding):
    x = _rand((2, 9, 11, 5), 22)
    w = _rand((3, 3, 5, 7), 23, 0.0)
    stats.reset()
    _grad_eagerly(
        lambda x, w: (relu_conv(x, w, stride, padding, PALLAS) ** 2).sum(),
        x, w)
    assert stats.total("act") == 1, stats.counts()
    assert stats.total("grad") == 1, stats.counts()


def test_depthwise_threaded_masks_match_oracle():
    """Per-group masks are column slices of the ONE bitmap: group g's slice
    of the im2col'd bitmap equals a fresh scan of group g's im2col'd data
    (the group-boundary granularity contract makes the slice exact)."""
    from repro.core.policy import grouped_gemm_block
    from repro.core.sparse_conv import (
        _conv_engine_fwd, _group_patches,
    )

    policy = PALLAS_U
    n, h, wd, c, groups = 2, 9, 11, 6, 2
    r = s = 3
    x_pre = _rand((n, h, wd, c), 40)
    w = _rand((3, 3, c // groups, 8), 41, 0.0)
    _, (st, _) = _conv_engine_fwd(x_pre, w, 1, "SAME", policy, True, groups)
    assert st.bitmap is not None
    gc = st.gran[1]
    x = jnp.maximum(x_pre, 0)
    plh = _pad_amounts(h, r, 1, "SAME")
    plw = _pad_amounts(wd, s, 1, "SAME")
    pad4 = (plh[0], plh[1], plw[0], plw[1])
    pm = _im2col(x, r, s, 1, pad4).reshape(-1, r * s * c)
    cg = c // groups
    blk = grouped_gemm_block(policy, (pm.shape[0], r * s * cg, 4), (1, gc, 1))
    pb = _patch_bitmap(st, (n, h, wd, c), r, s, 1, pad4)
    pbg = _group_patches(pb.bitmap, r * s, groups)
    derived = coarsen_bitmap(pbg, (1, gc), (blk[0], blk[1]))
    data_g = _group_patches(pm, r * s, groups)
    for g in range(groups):
        np.testing.assert_array_equal(
            derived[g], _bitmap_padded(data_g[g], blk[0], blk[1]))
    # per-group out_mask == fresh scan of the group's σ' column slice
    from repro.core.sparse_conv import _group_cols
    mask2d = (x_pre > 0).reshape(n * h * wd, c).astype(jnp.float32)
    om = coarsen_bitmap(_group_cols(st.bitmap, groups), (1, gc),
                        (blk[0], blk[2]))
    mg = _group_cols(mask2d, groups)
    for g in range(groups):
        np.testing.assert_array_equal(
            om[g], _bitmap_padded(mg[g], blk[0], blk[2]))


def test_depthwise_pw_chain_one_bitmap_per_activation():
    """dw→pw chain (the MobileNet block): each activation is encoded ONCE
    per step, each gradient scanned at most once — the per-activation
    budget holds across the depthwise boundary too."""
    from repro.core.sparse_conv import depthwise_relu_conv

    c = 8
    x = _rand((2, 8, 8, c), 42)
    wdw = _rand((3, 3, 1, c), 43, 0.0)
    wpw = _rand((1, 1, c, 12), 44, 0.0)

    def chain(x, wdw, wpw):
        y = depthwise_relu_conv(x, wdw, 1, "SAME", PALLAS)
        return (relu_conv(y, wpw, 1, "SAME", PALLAS) ** 2).sum()

    stats.reset()
    _grad_eagerly(chain, x, wdw, wpw)
    # two fused units (dw, pw) ⇒ two act encodes, two grad scans — exactly
    assert stats.total("act") == 2, stats.counts()
    assert stats.total("grad") == 2, stats.counts()
    assert stats.counts().get("conv:dense_fallback", 0) == 0


def _scan_ops(counts):
    """All standalone bitmap-scan launches, pallas and xla_ref alike."""
    return sum(v for k, v in counts.items()
               if k.startswith("scan_pallas:") or k.startswith("scan:"))


def test_pallas_scan_bitmap_is_opt_in_for_raw_inputs():
    """Standalone ``kernels.bitmap_scan`` survives ONLY as the opt-in entry
    scan of raw signed model inputs (``scan_signed_inputs=True``) — counted
    as ``scan_pallas:*``, with the XLA-reference ``scan:*`` key silent.
    Gradients never scan on any policy: dy bitmaps come from the producing
    GEMM's ``bitmap_emit`` epilogue (or a registry miss ⇒ no mask)."""
    x = _rand((2, 8, 8, 4), 45)
    w = _rand((3, 3, 4, 6), 46, 0.0)
    scanning = PALLAS.with_(scan_signed_inputs=True)
    stats.reset()
    _grad_eagerly(
        lambda x, w: (sconv(x, w, 1, "SAME", scanning) ** 2).sum(), x, w)
    c = stats.counts()
    assert c.get("scan_pallas:act", 0) == 1, c
    assert c.get("scan_pallas:grad", 0) == 0, c      # dy is never scanned
    assert c.get("scan:act", 0) == 0 and c.get("scan:grad", 0) == 0, c
    # default policy: NO standalone scan anywhere — the hot path is
    # scan-free and the dx GEMM emits its own bitmap at writeback
    stats.reset()
    _grad_eagerly(
        lambda x, w: (sconv(x, w, 1, "SAME", PALLAS) ** 2).sum(), x, w)
    c = stats.counts()
    assert _scan_ops(c) == 0, c
    assert c.get("emit:grad", 0) >= 1, c


def test_cnn_training_step_is_scan_free():
    """Full jitted CNN training step (vgg16 smoke geometry): every dy
    bitmap is emitted by the producing GEMM's epilogue, so ``scan_pallas:*``
    is identically zero in the step's traced graph — the tentpole claim."""
    from repro.models.cnn import build_cnn

    model = build_cnn("vgg16", image_size=8, width=0.0625, num_classes=10)
    params = model.init(jax.random.key(0))
    img = jax.random.normal(jax.random.key(1), (1, 8, 8, 3), jnp.float32)
    lbl = jax.random.randint(jax.random.key(2), (1,), 0, 10)

    @jax.jit
    def step(p, img, lbl):
        loss, g = jax.value_and_grad(
            lambda q: model.loss(q, img, lbl, PALLAS))(p)
        return jax.tree.map(lambda w, dw: w - 0.05 * dw, p, g), loss

    stats.reset()
    new_p, loss = step(params, img, lbl)
    jax.block_until_ready(loss)
    c = stats.counts()
    assert _scan_ops(c) == 0, c
    assert c.get("emit:grad", 0) >= 1, c             # epilogue is producing
    assert stats.total("act") >= 1, c                # fused encodes intact
    assert bool(np.isfinite(np.asarray(loss)))


def test_ffn_training_step_is_scan_free():
    """Full jitted FFN (relu) training step: the down-projection's backward
    dX GEMM emits the hidden gradient's bitmap; the up-projection's backward
    consumes it via the registry — zero standalone scans end to end."""
    from repro.models.ffn import FFNConfig, ffn_apply, ffn_init

    cfg = FFNConfig(d_model=16, d_ff=32, activation="relu",
                    sparse_policy=PALLAS)
    params = ffn_init(jax.random.key(10), cfg)
    x = jax.random.normal(jax.random.key(11), (32, 16), jnp.float32)
    y = jax.random.normal(jax.random.key(12), (32, 16), jnp.float32)

    @jax.jit
    def step(p, x, y):
        loss, g = jax.value_and_grad(
            lambda q: jnp.mean((ffn_apply(q, x, cfg) - y) ** 2))(p)
        return jax.tree.map(lambda w, dw: w - 0.05 * dw, p, g), loss

    stats.reset()
    new_p, loss = step(params, x, y)
    jax.block_until_ready(loss)
    c = stats.counts()
    assert _scan_ops(c) == 0, c
    assert c.get("emit:grad", 0) >= 1, c
    assert bool(np.isfinite(np.asarray(loss)))


def test_dc_policy_computes_no_bitmaps():
    x = _rand((16, 16), 24)
    w = _rand((16, 8), 25, 0.0)
    stats.reset()
    _grad_eagerly(lambda x, w: (act_matmul(x, w, pol.DC, "relu") ** 2).sum(),
                  x, w)
    # no bitmap computations, no queue builds — only the dispatcher's
    # normalized gemm:dense launch keys (fwd + dx + dw = 3)
    assert stats.total("act") == 0 and stats.total("grad") == 0, stats.counts()
    assert stats.queue_builds() == 0, stats.counts()
    assert stats.gemm_launches(schedule="dense", groups=1) == 3, stats.counts()
    assert stats.gemm_launches() == stats.total() == 3, stats.counts()


def test_granularity_helpers_divide_all_consumers():
    for block in [(8, 16, 8), (16, 16, 16), (128, 128, 128), (16, 8, 32)]:
        bm, bk, bn = block
        gr, gc = linear_act_granularity(block)
        assert bm % gr == 0 and bk % gr == 0          # rows + transposed cols
        assert bk % gc == 0 and bn % gc == 0 and bm % gc == 0
        for ch in (5, 16, 64, 384):
            g = conv_channel_granularity(ch, block)
            assert ch % g == 0 and bm % g == 0 and bk % g == 0 and bn % g == 0
