"""Verifier self-tests: zero violations on main, and every planted
mutation caught by the matching checker (the ISSUE's acceptance gate).

The mutant kernels set ``__module__`` to the real kernel module and import
``pl``/``jnp``/``jax`` from it *inside the body*, so the sanitizer's
module-global shim swap governs them exactly as it governs the real
kernels."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_audit, kernel_sanitizer as ks, lint
from repro.analysis.__main__ import main as analysis_main
from repro.core import policy as pol
from repro.kernels import ops

PALLAS_POLICY = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))


def codes(violations):
    return sorted({v.code for v in violations})


# ---------------------------------------------------------------------------
# Zero violations on main — the analyzer's contract for the shipped code
# ---------------------------------------------------------------------------

def test_ffn_relu_workload_clean():
    vs = jaxpr_audit.audit_fn(jaxpr_audit.WORKLOADS["ffn_relu"](),
                              workload="ffn_relu")
    assert vs == []


@pytest.mark.slow
@pytest.mark.parametrize("name", ["vgg16", "mobilenet"])
def test_cnn_workloads_clean(name):
    vs = jaxpr_audit.audit_fn(jaxpr_audit.WORKLOADS[name](), workload=name)
    assert vs == []


def test_kernel_sweep_clean():
    assert ks.sanitize_all() == []


def test_repo_lint_clean():
    assert lint.lint_paths(["src", "benchmarks", "examples"]) == []


def test_cli_kernel_and_lint_pass(tmp_path, capsys):
    out = tmp_path / "v.json"
    rc = analysis_main(["--fail-on-violation", "--skip", "jaxpr",
                        "--json", str(out)])
    assert rc == 0
    assert out.read_text() == "[]"


# ---------------------------------------------------------------------------
# Planted mutation: re-scanned dy bitmap → RESCAN
# ---------------------------------------------------------------------------

def test_mutation_rescanned_dy_bitmap():
    def rescan(dy):
        b1 = ops.bitmap_scan(dy, block=(8, 8), kind="grad")
        b2 = ops.bitmap_scan(dy, block=(8, 8), kind="grad")  # the mutation
        return b1.sum() + b2.sum()

    vs = jaxpr_audit.audit_fn(rescan, jnp.ones((16, 16)), workload="mut")
    assert "RESCAN" in codes(vs)


def test_scan_then_derive_is_not_a_rescan():
    from repro.core.sparse_tensor import coarsen_bitmap

    def ok(dy):
        b = ops.bitmap_scan(dy, block=(8, 8), kind="grad")
        return coarsen_bitmap(b, (1, 1), (2, 2)).sum()

    vs = jaxpr_audit.audit_fn(ok, jnp.ones((16, 16)), workload="ok")
    assert vs == []


# ---------------------------------------------------------------------------
# Planted mutation: dense GEMM on the hot path → DENSE_GEMM
# ---------------------------------------------------------------------------

def test_mutation_dense_fallback_gemm():
    def dense(x, w):
        return (x @ w).sum()   # dot_general outside any dispatch region

    vs = jaxpr_audit.audit_fn(dense, jnp.ones((16, 16)), jnp.ones((16, 16)),
                              workload="mut")
    assert "DENSE_GEMM" in codes(vs)


def test_mutation_adhoc_spec():
    def adhoc(x, w):
        spec = ops.GemmSpec(block=(8, 8, 8), schedule="predicated")
        bm = ops.bitmap_scan(x, block=(8, 8), kind="act")
        return ops.sparse_gemm(x, w, (bm, None), spec).sum()

    vs = jaxpr_audit.audit_fn(adhoc, jnp.ones((16, 16)), jnp.ones((16, 16)),
                              workload="mut")
    assert "SPEC_UNRESOLVED" in codes(vs)


def test_mutation_hand_rolled_mask():
    def underived(x, w):
        bm = (jnp.abs(x[:8, :8]).sum() > 0).astype(jnp.int32) \
            * jnp.ones((2, 2), jnp.int32)
        spec = PALLAS_POLICY.gemm_spec(dims=(16, 16, 16))
        return ops.sparse_gemm(x, w, (bm, None), spec).sum()

    vs = jaxpr_audit.audit_fn(underived, jnp.ones((16, 16)),
                              jnp.ones((16, 16)), workload="mut")
    assert "UNDERIVED_MASK" in codes(vs)


def test_mutation_dense_schedule():
    dense_pol = pol.IN_OUT.with_(kernel_impl="xla")

    def step(x, w):
        bm = ops.bitmap_scan(x, block=(8, 8), kind="act")
        spec = dense_pol.gemm_spec(dims=(16, 16, 16))
        return ops.sparse_gemm(x, w, (bm, None), spec).sum()

    vs = jaxpr_audit.audit_fn(step, jnp.ones((16, 16)), jnp.ones((16, 16)),
                              workload="mut", expect_pallas=True)
    assert "DENSE_SCHEDULE" in codes(vs)


# ---------------------------------------------------------------------------
# Planted mutation: double-written tile → DOUBLE_WRITE (kernel sanitizer)
# ---------------------------------------------------------------------------

def _geometry():
    r = np.random.RandomState(0)
    g, m, k, n, b = 1, 8, 8, 8, 4
    a = r.randn(g, m, k).astype(np.float32)
    bb = r.randn(g, k, n).astype(np.float32)
    ones = np.ones((g, 2, 2), np.int32)
    return a, bb, ones, b


def test_mutation_double_written_tile():
    def mut(out_m_ref, a_m_ref, b_m_ref, a_ref, b_ref, o_ref, acc_ref):
        from repro.kernels.masked_matmul import jnp, pl
        kk = pl.program_id(3)

        @pl.when(kk == 0)
        def _zero():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                                preferred_element_type=jnp.float32)
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)   # EVERY k, not last

    mut.__module__ = "repro.kernels.masked_matmul"
    a, bb, ones, b = _geometry()
    vs, _ = ks.run_predicated_grouped(a, bb, ones, ones, ones,
                                      bm=b, bk=b, bn=b, kernel_fn=mut)
    assert "DOUBLE_WRITE" in codes(vs)


def test_mutation_stale_accumulator():
    def mut(out_m_ref, a_m_ref, b_m_ref, a_ref, b_ref, o_ref, acc_ref):
        from repro.kernels.masked_matmul import jnp, pl
        kk = pl.program_id(3)
        nk = pl.num_programs(3)
        # MUTATION: no k==0 zeroing — carries the previous tile's sums.
        acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                                preferred_element_type=jnp.float32)

        @pl.when(kk == nk - 1)
        def _write():
            o_ref[0] = acc_ref[...].astype(o_ref.dtype)

    mut.__module__ = "repro.kernels.masked_matmul"
    a, bb, ones, b = _geometry()
    vs, _ = ks.run_predicated_grouped(a, bb, ones, ones, ones,
                                      bm=b, bk=b, bn=b, kernel_fn=mut)
    assert "ACC_READ_BEFORE_WRITE" in codes(vs)


def test_real_kernels_produce_reference_values():
    """The shadow run is an executable spec: its predicated output must
    equal masked dense numpy."""
    r = np.random.RandomState(1)
    g, m, k, n, b = 2, 8, 8, 8, 4
    a = r.randn(g, m, k).astype(np.float32)
    bb = r.randn(g, k, n).astype(np.float32)
    om = (r.rand(g, 2, 2) > 0.4).astype(np.int32)
    ones = np.ones((g, 2, 2), np.int32)
    vs, out = ks.run_predicated_grouped(a, bb, om, ones, ones,
                                        bm=b, bk=b, bn=b)
    assert vs == []
    ref = np.einsum("gmk,gkn->gmn", a, bb)
    mask = np.kron(om, np.ones((b, b))).astype(bool).reshape(g, m, n)
    assert np.allclose(out, np.where(mask, ref, 0.0), atol=1e-4)


# ---------------------------------------------------------------------------
# Planted mutation: out-of-capacity queue write → QUEUE_WRITE_OOB
# ---------------------------------------------------------------------------

def _queue_mutant(clamp: bool, dump_dead: bool):
    def mut(bm_ref, ii_ref, jj_ref, cnt_ref, carry_ref, *, cap, nj, lb):
        from repro.kernels.queue_builder import jax, jnp, pl
        b = pl.program_id(0)
        nb = pl.num_programs(0)

        @pl.when(b == 0)
        def _init():
            carry_ref[0] = 0
            ii_ref[...] = jnp.zeros_like(ii_ref)
            jj_ref[...] = jnp.zeros_like(jj_ref)

        flags = (bm_ref[...] != 0).astype(jnp.int32)[0]
        excl = jnp.cumsum(flags) - flags
        base = carry_ref[0]

        def _store(e, _):
            t = b * lb + e
            if dump_dead:
                slot = jnp.where(flags[e] != 0, base + excl[e], cap)
            else:
                slot = base + excl[e]       # MUTATION: dead rows not dumped
            if clamp:
                slot = jnp.minimum(slot, cap)
            # (without clamp, overflow writes land past the dump slot)
            ii_ref[pl.dslice(slot, 1), :] = jnp.full((1, 1), t // nj,
                                                     jnp.int32)
            jj_ref[pl.dslice(slot, 1), :] = jnp.full((1, 1), t % nj,
                                                     jnp.int32)
            return 0

        jax.lax.fori_loop(0, lb, _store, 0)
        carry_ref[0] = base + jnp.sum(flags)

        @pl.when(b == nb - 1)
        def _emit():
            cnt_ref[0, 0] = carry_ref[0]

    mut.__module__ = "repro.kernels.queue_builder"
    return mut


def test_mutation_out_of_capacity_queue_write():
    mut = _queue_mutant(clamp=False, dump_dead=True)
    vs, _ = ks.run_queue_builder(np.ones((4, 4), np.int32), capacity=5,
                                 launch_block=4, kernel_fn=mut)
    assert "QUEUE_WRITE_OOB" in codes(vs)


def test_mutation_dump_slot_leak():
    mut = _queue_mutant(clamp=True, dump_dead=False)
    bmp = (np.arange(16).reshape(4, 4) % 2).astype(np.int32)
    vs, _ = ks.run_queue_builder(bmp, capacity=16, launch_block=4,
                                 kernel_fn=mut)
    assert "DUMP_SLOT_LEAK" in codes(vs)


def test_queue_overflow_quarantined_on_real_kernel():
    """The REAL builder under overflow: live slots keep the reference
    prefix, the dump slot absorbs the rest, count reports the true total."""
    vs, (ii, jj, n_live) = ks.run_queue_builder(
        np.ones((4, 4), np.int32), capacity=5, launch_block=4)
    assert vs == []
    assert n_live == 16 and list(ii) == [0, 0, 0, 0, 1]


# ---------------------------------------------------------------------------
# Planted mutation: loose-kwarg call site → LOOSE_KWARG (lint)
# ---------------------------------------------------------------------------

def test_mutation_loose_kwarg_callsite():
    vs = lint.lint_source(
        "y = relu_matmul(x, w, compact=True, queue_builder='argsort')\n",
        path="src/repro/core/sparse_linear.py")
    assert codes(vs) == ["LOOSE_KWARG"]


def test_loose_kwargs_allowed_in_spec_construction():
    vs = lint.lint_source(
        "spec = policy.gemm_spec(dims=dims)\n"
        "p2 = SparsityPolicy(queue_builder='prefix_sum')\n"
        "p3 = p2.with_(queue_builder='argsort')\n",
        path="src/repro/core/x.py")
    assert vs == []


def test_lint_shim_call_and_ref_exemption():
    bad = lint.lint_source("out = ops.masked_matmul(a, b, m)\n",
                           path="src/repro/models/x.py")
    assert codes(bad) == ["SHIM_CALL"]
    ok = lint.lint_source("want = ref.masked_matmul(a, b, m)\n",
                          path="tests/x.py")
    assert ok == []
    # No kernels/ allowance anymore: the shims are deleted, so a bare call
    # breaks at runtime anywhere — including inside kernels/.
    in_kernels = lint.lint_source("out = masked_matmul(a, b, m)\n",
                                  path="src/repro/kernels/ops.py")
    assert codes(in_kernels) == ["SHIM_CALL"]


def test_lint_conv_fallback_and_waiver():
    bad = lint.lint_source(
        "def f(x, w):\n"
        "    return jax.lax.conv_general_dilated(x, w, (1, 1), 'SAME')\n",
        path="src/repro/models/x.py")
    assert codes(bad) == ["CONV_FALLBACK"]
    counted = lint.lint_source(
        "def f(x, w):\n"
        "    stats.record('conv:dense_fallback')\n"
        "    return jax.lax.conv_general_dilated(x, w, (1, 1), 'SAME')\n",
        path="src/repro/models/x.py")
    assert counted == []
    waived = lint.lint_source(
        "def f(x, w):\n"
        "    # dense oracle  # repro-lint: allow(CONV_FALLBACK)\n"
        "    return jax.lax.conv_general_dilated(x, w, (1, 1), 'SAME')\n",
        path="benchmarks/x.py")
    assert waived == []


def test_lint_stats_key_families():
    bad = lint.lint_source("stats.record('gemm:blocked:x')\n", path="a.py")
    assert codes(bad) == ["STATS_KEY"]
    bad2 = lint.lint_source("stats.record('bitmap:scan')\n", path="a.py")
    assert codes(bad2) == ["STATS_KEY"]
    ok = lint.lint_source(
        "stats.record('gemm:compact:4')\n"
        "stats.record('queue:prefix_sum')\n"
        "stats.record('conv:dense_fallback')\n", path="a.py")
    assert ok == []


# ---------------------------------------------------------------------------
# Instrumentation plumbing the checkers rely on
# ---------------------------------------------------------------------------

def test_gemm_event_provenance():
    with ops.collect_gemm_events() as events:
        jax.make_jaxpr(
            lambda x, w: ops.sparse_gemm(
                x, w, (jnp.ones((2, 2), jnp.int32), None),
                PALLAS_POLICY.gemm_spec(dims=(16, 16, 16)))
        )(jnp.ones((16, 16)), jnp.ones((16, 16)))
    assert [e.origin for e in events] == ["policy"]
    # origin is provenance, not identity: it must not affect spec equality.
    s1 = ops.GemmSpec(block=(8, 8, 8), schedule="compact")
    s2 = PALLAS_POLICY.gemm_spec(dims=(16, 16, 16))
    assert s1 == ops.GemmSpec(block=(8, 8, 8), schedule="compact",
                              origin="whatever")
    assert s2.origin == "policy"


def test_lifecycle_scopes_reach_the_jaxpr():
    from repro.kernels import stats

    def f(x):
        with stats.layer_scope("L0"):
            return ops.bitmap_scan(x, block=(8, 8), kind="act").sum()

    jx = jax.make_jaxpr(f)(jnp.ones((16, 16)))
    stacks = " / ".join(str(e.source_info.name_stack) for e in jx.eqns)
    assert "repro:scan:act" in stacks and "layer:L0" in stacks
