import os
import sys

# src/ layout import path (tests run with PYTHONPATH=src, but be robust)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device (the 512-device override
# belongs exclusively to launch/dryrun.py, see its module docstring).

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_stats_counters():
    """The stats counters, live-tile buffers and the autotune cache are
    process-global host state recorded at trace time, so any test that
    traces a sparse op leaks state into the next test.  Reset around every
    test so counter/decision assertions are order-independent."""
    from repro.kernels import autotune, stats
    stats.reset()
    autotune.reset()
    yield
    stats.reset()
    autotune.reset()
