import os
import sys

# src/ layout import path (tests run with PYTHONPATH=src, but be robust)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device (the 512-device override
# belongs exclusively to launch/dryrun.py, see its module docstring).
