import os
import sys

# src/ layout import path (tests run with PYTHONPATH=src, but be robust)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device (the 512-device override
# belongs exclusively to launch/dryrun.py, see its module docstring).

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_stats_counters():
    """The stats counters are process-global and record at trace time, so
    any test that traces a sparse op leaks counts into the next test.
    Reset around every test so counter assertions are order-independent."""
    from repro.kernels import stats
    stats.reset()
    yield
    stats.reset()
