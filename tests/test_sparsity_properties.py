"""Property-based tests (hypothesis) for the system's invariants.

Requires the ``dev`` extra (``pip install -e .[dev]``); skipped cleanly —
not a collection error — where hypothesis isn't installed.  Deterministic
sweep versions of the core invariants live in tests/test_bitmap_threading.py
so tier-1 coverage does not depend on this file.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import sparsity
from repro.kernels import ops, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def _matrix(draw, max_dim=48):
    m = draw(st.integers(4, max_dim))
    n = draw(st.integers(4, max_dim))
    seed = draw(st.integers(0, 2 ** 16))
    sp = draw(st.floats(0.0, 0.95))
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, n)).astype(np.float32)
    x *= rng.random((m, n)) > sp
    return jnp.asarray(x)


@given(_matrix())
def test_footprint_identity_through_relu(z):
    """Paper §3.2: zeros of relu(z) contain zeros of any δ⊙σ'(z)."""
    act = jnp.maximum(z, 0)
    delta = jnp.asarray(
        np.random.default_rng(0).standard_normal(z.shape), jnp.float32)
    grad_pre = delta * (z > 0)
    assert sparsity.footprints_identical(act, grad_pre)


@given(_matrix(), st.sampled_from([4, 8, 16]))
def test_capture_rate_bounds(x, b):
    m, n = x.shape
    xp = jnp.pad(x, ((0, -m % b), (0, -n % b)))
    c = float(sparsity.capture_rate(xp, b, b))
    assert 0.0 <= c <= 1.0
    # block sparsity never exceeds element sparsity
    assert float(sparsity.block_sparsity(xp, b, b)) <= \
        float(sparsity.element_sparsity(xp)) + 1e-6


@given(_matrix(max_dim=40), _matrix(max_dim=40), st.sampled_from([8, 16]))
def test_masked_matmul_matches_oracle(a, bmat, blk):
    k = min(a.shape[1], bmat.shape[0])
    a = a[:, :k]
    bmat = bmat[:k, :]
    m, n = a.shape[0], bmat.shape[1]
    rng = np.random.default_rng(1)
    mask = jnp.asarray(rng.random((m, n)) > 0.5, jnp.float32)
    mp = jnp.pad(mask, ((0, -m % blk), (0, -n % blk)))
    om = ref.block_any_nonzero(mp, blk, blk)
    got = ops.sparse_gemm(a, bmat, ops.GemmMasks(out=om),
                          ops.GemmSpec(block=(blk, blk, blk)))
    want = np.asarray(a, np.float32) @ np.asarray(bmat, np.float32)
    want = want * np.asarray(ref.expand_block_mask(om, blk, blk))[:m, :n]
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(st.integers(0, 2 ** 16), st.floats(0.1, 0.9))
def test_relu_encode_bitmap_is_conservative(seed, sp):
    """bitmap==0 ⇒ block truly all-zero (never skips live work)."""
    rng = np.random.default_rng(seed)
    z = rng.standard_normal((32, 32)).astype(np.float32)
    z *= rng.random((32, 32)) > sp
    y, bm = ops.relu_encode(jnp.asarray(z), block=(8, 8))
    y = np.asarray(y)
    bm = np.asarray(bm)
    for i in range(4):
        for j in range(4):
            blockvals = y[i * 8:(i + 1) * 8, j * 8:(j + 1) * 8]
            if bm[i, j] == 0:
                assert np.all(blockvals == 0)
            else:
                assert np.any(blockvals > 0)


@given(st.integers(0, 2 ** 16))
def test_quantize_error_feedback_contracts(seed):
    """int8 EF compression: accumulated error stays bounded (no drift)."""
    from repro.optim.compression import dequantize, quantize
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    err = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for _ in range(8):
        q, scale, err = quantize(g, err)
        total_sent = total_sent + dequantize(q, scale)
    # after k steps, Σ sent ≈ k·g with error ≤ one quantization step
    resid = np.abs(np.asarray(total_sent - 8 * g))
    assert resid.max() <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-5


@given(st.integers(2, 6), st.integers(0, 1000))
def test_chunked_xent_matches_full(nchunk, seed):
    from repro.models.transformer import chunked_xent
    rng = np.random.default_rng(seed)
    t, d, v = nchunk * 7, 16, 33
    h = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, v, (t,)), jnp.int32)
    got = chunked_xent(h, tgt, w, chunk=7)
    logits = h @ w
    want = (jax.nn.logsumexp(logits, -1)
            - jnp.take_along_axis(logits, tgt[:, None], 1)[:, 0]).mean()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
