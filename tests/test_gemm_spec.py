"""The spec-driven sparse_gemm collapse: one dispatch API, zero regressions.

Four contract families:
  1. BIT-EXACTNESS NET — ``sparse_gemm`` at G=1 is bit-identical to the
     pre-redesign 2-D orchestration (re-built here on the RETAINED 2-D
     reference kernels in kernels/masked_matmul.py) across
     {predicated, compact} × {none, sigma_prime epilogue} × queue capacity
     {unbounded, exactly-live, overflow→fallback}.
  2. EPILOGUE COMPOSITION — the ``(sigma_prime, bitmap_emit)`` stage tuple
     emits, at accumulator writeback, a bitmap bit-identical to a fresh
     ``bitmap_scan`` of the returned (post-σ′) output, across
     {predicated, compact} × {G=1, grouped} × overflow-fallback; and the
     autotune cache key ignores epilogue/emit_gran (tuples included).
  3. policy→spec resolution (`SparsityPolicy.gemm_spec`) lands the right
     schedule/queue/tiles, incl. grouped_gemm_block degenerate tiles, and
     the default policy still builds queues sort-free
     (``stats.queue_builds("argsort") == 0``).
  4. the dispatcher's normalized ``gemm:<schedule>:<g>`` stats keys and
     ``GemmSpec.launch_geometry``'s pad/grid/queue arithmetic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.kernels import ops, ref, stats
from repro.kernels.masked_matmul import (
    compact_masked_matmul_kernel, masked_matmul_kernel,
)
from repro.kernels.ops import GemmMasks, GemmSpec
from repro.kernels.shapes import ceil_to, pad_mask, pad_to


# ---------------------------------------------------------------------------
# 1. bit-exactness vs the pre-redesign 2-D orchestration
# ---------------------------------------------------------------------------

def _legacy_masked_matmul(a, b, out_mask=None, a_mask=None, b_mask=None, *,
                          block, out_dtype=jnp.float32, compact=False,
                          max_active_blocks=None, epilogue_mult=None):
    """The pre-redesign 2-D orchestrator, frozen verbatim on the retained
    2-D kernels — the reference ``sparse_gemm(G=1)`` must match to the bit."""
    m, k = a.shape
    k2, n = b.shape
    bm, bk, bn = block
    mp, kp, np_ = ceil_to(m, bm), ceil_to(k, bk), ceil_to(n, bn)
    ni, nk, nj = mp // bm, kp // bk, np_ // bn
    a_p, b_p = pad_to(a, mp, kp), pad_to(b, kp, np_)
    mult_p = None
    if epilogue_mult is not None:
        mult_p = pad_to(epilogue_mult.astype(jnp.float32), mp, np_)
    om = pad_mask(out_mask, ni, nj)
    am = pad_mask(a_mask, ni, nk)
    bmask = pad_mask(b_mask, nk, nj)

    def _predicated():
        return masked_matmul_kernel(
            a_p, b_p, om, am, bmask, bm=bm, bk=bk, bn=bn,
            out_dtype=out_dtype, epilogue_mult=mult_p, interpret=True)

    if compact:
        s_cap = max_active_blocks if max_active_blocks is not None \
            else ni * nj
        ii, jj, n_live_v = ops.build_queue(om, capacity=s_cap)
        n_live = n_live_v[0]
        n_active = jnp.minimum(n_live, s_cap).reshape(1)

        def _compact():
            compacted = compact_masked_matmul_kernel(
                a_p, b_p, ii, jj, n_active, am, bmask, bm=bm, bk=bk, bn=bn,
                out_dtype=out_dtype, epilogue_mult=mult_p, interpret=True)
            live = (jnp.arange(s_cap) < n_active[0]).astype(out_dtype)
            masked = compacted * live[:, None, None]
            si = jnp.where(jnp.arange(s_cap) < n_active[0], ii, 0)
            sj = jnp.where(jnp.arange(s_cap) < n_active[0], jj, 0)
            out_tiles = jnp.zeros((ni, nj, bm, bn), out_dtype)
            out_tiles = out_tiles.at[si, sj].add(masked)
            return out_tiles.transpose(0, 2, 1, 3).reshape(mp, np_)

        if s_cap >= ni * nj:
            out = _compact()
        else:
            out = jax.lax.cond(n_live > s_cap, _predicated, _compact)
    else:
        out = _predicated()
    return out[:m, :n]


def _operands(m, k, n, key, sparsity=0.6):
    rng = np.random.default_rng(key)
    a = rng.standard_normal((m, k)).astype(np.float32)
    a *= rng.random((m, k)) > sparsity
    b = rng.standard_normal((k, n)).astype(np.float32)
    mask = (rng.random((m, n)) > sparsity).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(mask)


@pytest.mark.parametrize("shape", [(40, 24, 48), (33, 17, 25), (32, 32, 32)])
@pytest.mark.parametrize("schedule", ["predicated", "compact"])
@pytest.mark.parametrize("epilogue", ["none", "sigma_prime"])
def test_g1_sparse_gemm_bit_exact_vs_pre_redesign(shape, schedule, epilogue):
    """ACCEPTANCE: the G=1 lowering of the grouped engine reproduces the
    old 2-D orchestration to the BIT on every schedule × epilogue cell."""
    m, k, n = shape
    a, b, mask = _operands(m, k, n, key=hash(shape) % 1000)
    bm, bk, bn = 8, 8, 16
    om = ref.block_any_nonzero(
        jnp.pad(mask, ((0, -m % bm), (0, -n % bn))), bm, bn)
    am = ref.block_any_nonzero(
        jnp.pad(a, ((0, -m % bm), (0, -k % bk))), bm, bk)
    mult = mask if epilogue == "sigma_prime" else None
    spec = GemmSpec(block=(bm, bk, bn), schedule=schedule, epilogue=epilogue,
                    interpret=True)
    got = ops.sparse_gemm(a, b, GemmMasks(om, am, None), spec,
                          epilogue_mult=mult)
    want = _legacy_masked_matmul(
        a, b, om, am, block=(bm, bk, bn),
        compact=(schedule == "compact"), epilogue_mult=mult)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("epilogue", ["none", "sigma_prime"])
@pytest.mark.parametrize("cap_kind", ["exact", "overflow"])
def test_g1_bounded_queue_and_overflow_bit_exact(epilogue, cap_kind):
    """Compact × bounded capacity: exactly-live stays on the queue path,
    one-below-live triggers the predicated fallback — both bit-identical
    to the pre-redesign orchestration of the same request."""
    m, k, n = 40, 24, 48
    a, b, mask = _operands(m, k, n, key=7)
    om = ref.block_any_nonzero(mask, 8, 16)
    n_live = int(np.asarray(om).sum())
    cap = n_live if cap_kind == "exact" else n_live - 1
    mult = mask if epilogue == "sigma_prime" else None
    spec = GemmSpec(block=(8, 8, 16), schedule="compact", epilogue=epilogue,
                    max_active_blocks=cap, interpret=True)
    got = ops.sparse_gemm(a, b, GemmMasks(out=om), spec, epilogue_mult=mult)
    want = _legacy_masked_matmul(a, b, om, block=(8, 8, 16), compact=True,
                                 max_active_blocks=cap, epilogue_mult=mult)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ...and both equal the oracle (the fallback never truncates)
    oracle = ref.masked_matmul(a, b, out_mask=om, bm=8, bk=8, bn=16,
                               epilogue_mult=mult)
    np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# 2. composable epilogue stages — bitmap_emit at accumulator writeback
# ---------------------------------------------------------------------------

def _scan_after_gemm_reference(out, emit_gran):
    """The separate-pass producer this PR retires: a fresh ``bitmap_scan``
    of the (already returned) GEMM output.  The emitted bitmap must equal
    it bit-for-bit."""
    return ops.bitmap_scan(out, block=emit_gran, kind="ref")


@pytest.mark.parametrize("schedule", ["predicated", "compact", "dense"])
@pytest.mark.parametrize("stages", [("bitmap_emit",),
                                    ("sigma_prime", "bitmap_emit")])
def test_emit_epilogue_matches_scan_after_gemm_g1(schedule, stages):
    """ACCEPTANCE: the emitted bitmap == scan-of-output, and the output
    itself is unchanged by staging emission — on every schedule, with and
    without the σ′ stage composed in (bits describe POST-σ′ values)."""
    m, k, n = 40, 24, 48
    a, b, mask = _operands(m, k, n, key=23)
    om = ref.block_any_nonzero(
        jnp.pad(mask, ((0, -m % 8), (0, -n % 16))), 8, 16)
    mult = mask if "sigma_prime" in stages else None
    base = GemmSpec(block=(8, 8, 16), schedule=schedule,
                    epilogue="sigma_prime" if mult is not None else "none",
                    interpret=True)
    plain = ops.sparse_gemm(a, b, GemmMasks(out=om), base,
                            epilogue_mult=mult)
    spec = base.with_(epilogue=stages, emit_gran=(4, 4))
    out, bits = ops.sparse_gemm(a, b, GemmMasks(out=om), spec,
                                epilogue_mult=mult)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))
    want = _scan_after_gemm_reference(out, (4, 4))
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(want))


@pytest.mark.parametrize("cap_kind", ["unbounded", "exact", "overflow"])
def test_emit_epilogue_grouped_and_overflow_fallback(cap_kind):
    """Grouped emission across queue capacities: the runtime predicated
    fallback must return the same (out, bits) pytree as the queue path."""
    g, m, k, n = 3, 24, 16, 24
    a, b, mask = _operands(m, k, n, key=29)
    ag = jnp.stack([a, a * 2, a * 3])
    bg = jnp.stack([b, b, b])
    omg = jnp.stack([ref.block_any_nonzero(mask, 8, 8)] * g)
    multg = jnp.stack([mask, mask, mask])
    n_live = int(np.asarray(omg).sum())
    cap = {"unbounded": None, "exact": n_live,
           "overflow": n_live - 1}[cap_kind]
    spec = GemmSpec(block=(8, 8, 8), groups=g, schedule="compact",
                    epilogue=("sigma_prime", "bitmap_emit"),
                    emit_gran=(8, 8), max_active_blocks=cap, interpret=True)
    out, bits = ops.sparse_gemm(ag, bg, GemmMasks(out=omg), spec,
                                epilogue_mult=multg)
    want_out = ops.sparse_gemm(
        ag, bg, GemmMasks(out=omg),
        spec.with_(epilogue="sigma_prime", emit_gran=None,
                   max_active_blocks=None),
        epilogue_mult=multg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want_out))
    for gi in range(g):
        want_bits = _scan_after_gemm_reference(out[gi], (8, 8))
        np.testing.assert_array_equal(np.asarray(bits[gi]),
                                      np.asarray(want_bits))


def test_autotune_key_excludes_epilogue_tuple_and_emit_gran():
    """The autotuner must share measurements across epilogue variants: the
    cache key is (block, groups, queue_builder, padded) — staging
    sigma_prime/bitmap_emit (and the emit_gran it requires) or changing
    out_dtype must NOT fork the key."""
    from repro.kernels import autotune

    dims = (64, 32, 64)
    base = GemmSpec(block=(8, 8, 8), schedule="compact")
    variants = [
        base,
        base.with_(epilogue=("sigma_prime",)),
        base.with_(epilogue=("bitmap_emit",), emit_gran=(4, 8)),
        base.with_(epilogue=("sigma_prime", "bitmap_emit"),
                   emit_gran=(8, 8)),
        base.with_(schedule="predicated", out_dtype=jnp.bfloat16),
    ]
    keys = {autotune.key_for(s, dims) for s in variants}
    assert len(keys) == 1, keys


# ---------------------------------------------------------------------------
# 3. policy → spec resolution
# ---------------------------------------------------------------------------

def test_policy_gemm_spec_resolution():
    p = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 16, 8),
                            queue_builder="argsort")
    s = p.gemm_spec(groups=1)
    assert (s.schedule, s.block, s.queue_builder, s.groups) \
        == ("compact", (8, 16, 8), "argsort", 1)
    assert p.with_(work_redistribution=False).gemm_spec().schedule \
        == "predicated"
    assert pol.IN_OUT.gemm_spec().schedule == "dense"       # xla_ref
    assert pol.DC.gemm_spec().schedule == "dense"
    # degenerate grouped tiles == the grouped_gemm_block rule, any G incl. 1
    for g in (1, 8):
        s = p.gemm_spec(groups=g, dims=(4096, 9, 1), grans=(1, 1, 1))
        assert s.block == pol.grouped_gemm_block(p, (4096, 9, 1), (1, 1, 1))
        assert s.block == (8, 9, 1)
    # fused-epilogue declaration (normalized to the canonical stage tuple)
    assert p.gemm_spec(fused_epilogue=True).epilogue == ("sigma_prime",)
    assert p.gemm_spec(fused_epilogue=False).epilogue == ()


def test_default_policy_training_step_is_sort_free_and_spec_routed():
    """End-to-end: an IN_OUT_WR step dispatches every GEMM through
    sparse_gemm (compact schedule) and never builds a queue by sorting."""
    from repro.core.sparse_linear import relu_matmul

    policy = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 8, 8))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    stats.reset()
    jax.grad(lambda x, w: (relu_matmul(x, w, policy) ** 2).sum(), (0, 1))(x, w)
    assert stats.queue_builds("argsort") == 0, stats.counts()
    assert stats.gemm_launches() == stats.gemm_launches(schedule="compact"), \
        stats.counts()
    assert stats.gemm_launches(schedule="compact", groups=1) == 3  # y, dx, dW


# ---------------------------------------------------------------------------
# 4. spec validation, stats keys, launch geometry
# ---------------------------------------------------------------------------

def test_gemm_spec_validates():
    with pytest.raises(ValueError, match="schedule"):
        GemmSpec(schedule="eager")
    with pytest.raises(ValueError, match="epilogue"):
        GemmSpec(epilogue="relu")
    with pytest.raises(ValueError, match="epilogue"):
        GemmSpec(epilogue=("sigma_prime", "sigma_prime"))   # duplicate stage
    with pytest.raises(ValueError, match="emit_gran"):
        GemmSpec(epilogue=("bitmap_emit",))                 # gran required
    with pytest.raises(ValueError, match="emit_gran"):
        GemmSpec(epilogue=("bitmap_emit",), emit_gran=(3, 8))  # 3 ∤ bm=128
    with pytest.raises(ValueError, match="emit_gran"):
        GemmSpec(emit_gran=(8, 8))                          # gran w/o stage
    # legacy spellings still normalize
    assert GemmSpec(epilogue="none").epilogue == ()
    assert GemmSpec(epilogue=None).epilogue == ()
    assert GemmSpec(epilogue="sigma_prime").epilogue == ("sigma_prime",)
    # canonical order is enforced regardless of declaration order
    s = GemmSpec(epilogue=("bitmap_emit", "sigma_prime"), emit_gran=(8, 8))
    assert s.epilogue == ("sigma_prime", "bitmap_emit")
    assert s.fuses_mult and s.emits_bitmap
    with pytest.raises(ValueError, match="groups"):
        GemmSpec(groups=0)
    a = jnp.ones((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="groups"):
        ops.sparse_gemm(a, a, None, GemmSpec(groups=2))
    with pytest.raises(ValueError, match="epilogue"):
        ops.sparse_gemm(a, a, None, GemmSpec(), epilogue_mult=a)
    with pytest.raises(ValueError, match="epilogue"):
        ops.sparse_gemm(a, a, None, GemmSpec(epilogue="sigma_prime"))
    with pytest.raises(ValueError, match="group axis"):
        ops.sparse_gemm(a[None], a[None], None, GemmSpec(groups=2))


def test_dispatch_records_normalized_stats_keys():
    a = jnp.ones((8, 8), jnp.float32)
    stats.reset()
    ops.sparse_gemm(a, a, None, GemmSpec(block=(8, 8, 8)))
    ops.sparse_gemm(a[None], a[None], None,
                    GemmSpec(block=(8, 8, 8), schedule="compact", groups=1))
    ops.sparse_gemm(a, a, None, GemmSpec(schedule="dense"))
    c = stats.counts()
    assert c["gemm:predicated:1"] == 1 and c["gemm:compact:1"] == 1 \
        and c["gemm:dense:1"] == 1, c
    assert stats.gemm_launches() == 3
    assert stats.gemm_launches(schedule="compact") == 1
    # legacy key heads alias onto the normalized family
    stats.record("mm:predicated:1")
    assert stats.counts()["gemm:predicated:1"] == 2


def test_launch_geometry_matches_dispatch_contract():
    s = GemmSpec(block=(8, 8, 16), groups=3, schedule="compact")
    g = s.launch_geometry(33, 17, 25)           # ni=5, nk=3, nj=2
    assert g["padded"] == (3, 40, 24, 32)
    assert g["queue_capacity"] == 3 * 5 * 2
    assert g["grid"] == (30, 3)
    assert g["fallback_grid"] == (3, 5, 2, 3)
    s2 = s.with_(schedule="predicated", groups=1)
    assert s2.launch_geometry(33, 17, 25)["grid"] == (1, 5, 2, 3)
    assert s.with_(schedule="dense").launch_geometry(33, 17, 25)["grid"] == ()


# ---------------------------------------------------------------------------
# 5. launch-geometry edge cases: the degenerate shapes real models hit
# ---------------------------------------------------------------------------

def test_launch_geometry_degenerate_depthwise_k():
    """Depthwise conv: per-group K = R·S = 9, far below the nominal 128
    block.  grouped_gemm_block must shrink the K edge to 9 (one K step,
    per-patch-row masking still live), not pad 14x and mask nothing."""
    p = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(128, 128, 128))
    m, k, n = 64, 9, 8                      # (M, R*S, C_out/G) per group
    spec = p.gemm_spec(groups=8, dims=(m, k, n))
    assert spec.block[1] == 9               # degenerate K edge
    g = spec.launch_geometry(m, k, n)
    assert g["padded"][2] == 9              # K axis NOT padded to 128
    assert g["grid"][1] == 1                # nk == 1: a single K step
    # masking stays live: the queue spans all groups' output tiles
    assert g["queue_capacity"] == 8 * g["fallback_grid"][1] \
        * g["fallback_grid"][2]


def test_launch_geometry_g1_keeps_leading_group_axis():
    """G=1 is the 2-D special case but the launch stays a GROUPED launch:
    the grid keeps its leading group axis of extent 1 (one kernel family,
    docs/gemm_api.md), and padding only touches the trailing dims."""
    spec = GemmSpec(block=(8, 8, 8), groups=1, schedule="predicated")
    g = spec.launch_geometry(12, 20, 8)
    assert g["grid"] == (1, 2, 1, 3)        # leading axis present, extent 1
    assert g["padded"] == (1, 16, 24, 8)
    # compact at G=1: queue capacity counts (1, ni, nj) tiles
    gc = spec.with_(schedule="compact").launch_geometry(12, 20, 8)
    assert gc["queue_capacity"] == 1 * 2 * 1
    assert gc["grid"] == (2, 3)
    assert gc["fallback_grid"] == (1, 2, 1, 3)


def test_exact_capacity_queue_leaves_dump_slot_unused():
    """n_live == capacity: every queue slot is live, nothing overflows, and
    the dump slot past the queue stays untouched — proven on the REAL
    prefix-sum kernel by the sanitizer's shadow write log."""
    from repro.analysis import kernel_sanitizer as ks
    from repro.core.workredist import static_queue_order

    bmp = np.ones((4, 4), np.int32)         # 16 live == capacity 16
    vs, (ii, jj, n_live) = ks.run_queue_builder(
        bmp, capacity=16, launch_block=4)
    assert vs == []                         # incl. DUMP_SLOT_LEAK clean
    ref_ii, ref_jj, ref_n = static_queue_order(bmp, 16)
    assert n_live == ref_n == 16
    assert np.array_equal(ii, ref_ii) and np.array_equal(jj, ref_jj)

    # the dispatcher's geometry agrees: exactly-live max_active_blocks
    # yields a queue of that capacity with the grid sized to it
    spec = GemmSpec(block=(8, 8, 8), groups=1, schedule="compact",
                    max_active_blocks=16)
    g = spec.launch_geometry(32, 16, 32)
    assert g["queue_capacity"] == 16
    assert g["grid"] == (16, 2)
