"""Sparsity-on-the-wire: bitmap-compressed collectives and the shard_map
training step (docs/sharding.md).

Two device regimes share this file:

  * Any-device cells run on whatever the process sees (tier-1 CI: ONE
    device — conftest.py deliberately sets no
    ``--xla_force_host_platform_device_count`` override).  A 1-device
    psum is still the full traced path: queue build, compact gather,
    runtime cutoff branch, counters.
  * ``requires8`` cells assert the actual multi-shard contracts
    (spmd-vs-jit equivalence, one-encode-across-the-mesh) and skip
    unless ≥8 devices are visible.  The sanctioned way to provide them
    is the ENVIRONMENT, not conftest: the ``sharded-smoke`` CI job (and
    a local run) exports ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    before pytest starts.  ``test_eight_device_rerun_subprocess`` (slow)
    does exactly that from a 1-device parent, so the 8-device cells stay
    reachable from a plain checkout too.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import policy as pol
from repro.core.sparse_linear import _act_matmul_fwd, act_matmul
from repro.core.sparse_tensor import lookup_grad_bitmap
from repro.kernels import stats
from repro.sharding import spmd_step
from repro.sharding.collectives import dense_psum, psum_grads, sparse_psum
from repro.sharding.partition import bitmap_pspec

PALLAS = pol.IN_OUT_WR.with_(kernel_impl="pallas", block=(8, 16, 8))

requires8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(set in the environment, never in conftest)")


def _data_mesh():
    return jax.make_mesh((jax.device_count(),), ("data",))


def _correlated_stack(n_dev, m, n, gran, live, seed=0):
    """(n_dev, m, n) data + (n_dev, mb, nb) bitmaps with the SAME block
    pattern on every shard — the dW regime (shards share σ′ geometry).
    The live count is exact: an uncorrelated Bernoulli draw per shard
    would union to ~dense and defeat the compressed path."""
    g0, g1 = gran
    mb, nb = -(-m // g0), -(-n // g1)
    rng = np.random.default_rng(seed)
    count = max(1, min(mb * nb, round(live * mb * nb)))
    bm = np.zeros(mb * nb, np.int32)
    bm[rng.permutation(mb * nb)[:count]] = 1
    bm = bm.reshape(mb, nb)
    expand = np.repeat(np.repeat(bm, g0, 0), g1, 1)[:m, :n]
    data = rng.standard_normal((n_dev, m, n)).astype(np.float32) \
        * expand[None].astype(np.float32)
    bits = np.broadcast_to(bm, (n_dev, mb, nb)).copy()
    return data, bits


def _reduce_fn(gran, cutoff, mesh=None):
    mesh = mesh or _data_mesh()
    axes = tuple(mesh.axis_names)

    def body(x, b):
        return sparse_psum(x[0], b[0], gran, axis_name=axes, cutoff=cutoff,
                           return_bits=True)

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(axes), P(axes)),
        out_specs=(P(), P()), check_rep=False))


# ---------------------------------------------------------------------------
# sparse_psum == dense all-reduce (any device count)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("live", [0.25, 1.0])
def test_sparse_psum_matches_dense_allreduce(live):
    """The compressed reduce (and its past-cutoff fallback) is EXACT
    against the numpy sum of all shard contributions: a union-dead block
    is all-zero on every shard, so dropping it from the wire loses
    nothing; live blocks travel unmodified."""
    n_dev = jax.device_count()
    gran = (4, 4)
    data, bits = _correlated_stack(n_dev, 32, 32, gran, live, seed=3)
    stats.reset()
    out, union = _reduce_fn(gran, cutoff=0.5)(
        jnp.asarray(data), jnp.asarray(bits))
    jax.block_until_ready(out)
    np.testing.assert_allclose(np.asarray(out), data.sum(0), atol=1e-5)
    c = stats.counts()
    assert c.get("collective:bitmap_psum", 0) >= 1, c
    if live <= 0.5:
        # compressed path taken on every shard, fallback on none
        assert c.get("collective:compressed", 0) == n_dev, c
        assert c.get("collective:dense_fallback", 0) == 0, c
        np.testing.assert_array_equal(
            (np.asarray(union) > 0).astype(np.int32), bits[0])
    else:
        assert c.get("collective:dense_fallback", 0) == n_dev, c
        assert c.get("collective:compressed", 0) == 0, c


def test_sparse_psum_cutoff_admitting_all_blocks_is_dense():
    """capacity ≥ nblocks ⇒ the compressed machinery cannot move fewer
    bytes than the dense reduce, so sparse_psum short-circuits to the
    tagged dense path at trace time (no queue, no cond)."""
    n_dev = jax.device_count()
    gran = (4, 4)
    data, bits = _correlated_stack(n_dev, 8, 8, gran, 0.5, seed=4)
    stats.reset()
    out, _ = _reduce_fn(gran, cutoff=1.0)(
        jnp.asarray(data), jnp.asarray(bits))
    jax.block_until_ready(out)
    np.testing.assert_allclose(np.asarray(out), data.sum(0), atol=1e-5)
    c = stats.counts()
    assert c.get("collective:dense", 0) >= 1, c
    assert c.get("collective:compressed", 0) == 0, c


def test_dense_psum_records_collective_key():
    mesh = _data_mesh()
    x = jnp.ones((jax.device_count(), 4, 4), jnp.float32)
    stats.reset()
    fn = jax.jit(shard_map(lambda v: dense_psum(v[0], axis_name="data"),
                           mesh=mesh, in_specs=(P("data"),),
                           out_specs=P(), check_rep=False))
    jax.block_until_ready(fn(x))
    assert stats.counts().get("collective:dense", 0) == 1


def test_psum_grads_routes_by_registry():
    """Pytree leaves with a registered bitmap take the compressed reduce;
    bias-like leaves (no bitmap) the tagged dense one — and the registry
    consult is a PEEK (no registry:miss inflation from structural
    misses).  The grads are produced INSIDE the shard_map body trace, as
    the training step does: the registry is keyed by object identity, so
    the WG bitmap registered by the backward pass is only visible on the
    very tracers that backward returned."""
    mesh = _data_mesh()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    params = {"w": jnp.asarray(rng.standard_normal((16, 16)), jnp.float32),
              "b": jnp.zeros((16,), jnp.float32)}
    stats.reset()

    def body(p):
        def loss(q):
            return ((act_matmul(x, q["w"], PALLAS, "relu")
                     + q["b"]) ** 2).sum()
        grads = jax.grad(loss)(p)
        return psum_grads(grads, axis_name=("data",), cutoff=0.5)

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), check_rep=False))
    out = fn(params)
    jax.block_until_ready(out)
    c = stats.counts()
    assert c.get("collective:bitmap_psum", 0) == 1, c   # dw leaf compressed
    assert c.get("collective:dense", 0) == 1, c         # bias leaf dense

    # peek, not lookup: the backward pass itself records its own registry
    # consults, but routing the grads through psum_grads must add ZERO
    # misses on top (every non-bitmap leaf it probes is a structural miss
    # that would otherwise poison the guard's miss-delta budget)
    misses_with = c.get("registry:miss", 0)
    stats.reset()
    jax.jit(lambda p: jax.grad(
        lambda q: ((act_matmul(x, q["w"], PALLAS, "relu")
                    + q["b"]) ** 2).sum())(p)).lower(params)
    assert stats.counts().get("registry:miss", 0) == misses_with


# ---------------------------------------------------------------------------
# WG bitmap registration (the registry hand-off the collective consumes)
# ---------------------------------------------------------------------------

def test_wg_bitmap_registered_for_linear_grads():
    """The backward dW of act_matmul registers a derived WG bitmap against
    the exact returned array, and the bitmap is CONSERVATIVE: a dead bit
    ⇒ that block of dW is exactly zero (masks may only err toward live —
    the invariant that makes dropping dead blocks from the wire exact)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((32, 16)) *
                    (rng.random((32, 16)) > 0.6), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    dw = jax.grad(lambda w_: (act_matmul(x, w_, PALLAS, "relu") ** 2).sum(),
                  )(w)
    hit = lookup_grad_bitmap(dw, peek=True)
    assert hit is not None
    bitmap, gran = hit
    g0, g1 = gran
    bnp, dnp = np.asarray(bitmap), np.asarray(dw)
    for i in range(bnp.shape[0]):
        for j in range(bnp.shape[1]):
            if bnp[i, j] == 0:
                blockv = dnp[i * g0:(i + 1) * g0, j * g1:(j + 1) * g1]
                assert not blockv.any(), (i, j)


# ---------------------------------------------------------------------------
# Mask slicing (pure contract — no mesh needed)
# ---------------------------------------------------------------------------

def test_shard_bitmap_is_row_slice_of_global_bitmap():
    """The spmd design's no-rescan guarantee rests on this: encoding a
    row-shard of the batch yields EXACTLY the matching row-slice of the
    global forward bitmap, whenever the shard boundary lands on a
    granularity-cell boundary (which `partition.bitmap_pspec` enforces
    for sharded carriers).  So per-shard SparseTensor masks ARE slices of
    the one forward bitmap — nothing is recomputed per shard."""
    n_shards, m, k = 8, 64, 16
    rng = np.random.default_rng(6)
    x_pre = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, 8)), jnp.float32)
    _, (st_g, _) = _act_matmul_fwd(x_pre, w, PALLAS, "relu")
    g0 = st_g.gran[0]
    rows = m // n_shards
    assert rows % g0 == 0, "shard boundary must land on a bitmap cell"
    for s in range(n_shards):
        shard = x_pre[s * rows:(s + 1) * rows]
        _, (st_s, _) = _act_matmul_fwd(shard, w, PALLAS, "relu")
        np.testing.assert_array_equal(
            np.asarray(st_s.bitmap),
            np.asarray(st_g.bitmap)[s * rows // g0:(s + 1) * rows // g0])


def test_bitmap_pspec_alignment_rules():
    """A bitmap dim mirrors its data dim's mesh axes only when every
    shard holds a whole number of granularity cells
    (dim % (axis_size · gran) == 0); otherwise it replicates."""
    mesh = _data_mesh()
    n = jax.device_count()
    gran = (8, 8)
    # aligned: rows divisible by axis_size * gran[0]
    spec = bitmap_pspec((8 * 8 * n, 32), P("data", None), gran, mesh)
    assert spec == P("data", None)
    # unsharded dims always replicate on the bitmap
    spec = bitmap_pspec((8 * 8 * n, 32), P(None, None), gran, mesh)
    assert spec == P(None, None)
    if n > 1:
        # rows divisible by gran but NOT by axis_size*gran: a shard
        # boundary would straddle a cell → replicate (conservative)
        spec = bitmap_pspec((8 * (n + 1), 32), P("data", None), gran, mesh)
        assert spec == P(None, None)


# ---------------------------------------------------------------------------
# Fault containment (the chaos-matrix case, run inline)
# ---------------------------------------------------------------------------

def test_collective_drop_fault_detected_and_survived():
    from repro.runtime import faults
    row = faults._case_collective_drop()
    assert row.detected, row.detail
    assert row.survived, row.detail
    assert row.ok


# ---------------------------------------------------------------------------
# BENCH_9 schema
# ---------------------------------------------------------------------------

def test_bench9_smoke_document_passes_schema():
    from benchmarks import wallclock
    doc = wallclock.run_collective_bench(smoke=True)
    assert wallclock.check_collective_schema(doc) == []
    assert doc["bench"] == "BENCH_9"
    # runtime counting restored after the bench disabled it
    assert stats.set_runtime_counting(True) is True


def test_bench9_schema_rejects_drift():
    from benchmarks import wallclock
    rows = []
    for mesh_name in ("8",):
        for live in wallclock.COLLECTIVE_LIVE_FRACS:
            for variant in wallclock.COLLECTIVE_VARIANTS:
                rows.append({
                    "table": "collective", "mesh": mesh_name, "devices": 8,
                    "m": 512, "n": 256, "block": "32x256",
                    "live_frac": live,
                    "cutoff": wallclock.COLLECTIVE_CUTOFF,
                    "variant": variant, "us_median": 100.0, "us_iqr": 1.0,
                    "reps": 3, "warmup": 1})
    doc = {"schema_version": wallclock.SCHEMA_VERSION, "bench": "BENCH_9",
           "jax_backend": "cpu", "geometry": "smoke", "rows": rows}
    assert wallclock.check_collective_schema(doc) == []

    bad = {**doc, "rows": [dict(r, extra=1) for r in rows]}
    assert any("key drift" in e
               for e in wallclock.check_collective_schema(bad))
    bad = {**doc, "rows": [dict(r, variant="gossip") for r in rows]}
    assert any("variant" in e
               for e in wallclock.check_collective_schema(bad))
    bad = {**doc, "rows": rows[:2]}
    assert any("coverage" in e
               for e in wallclock.check_collective_schema(bad))


def test_bench9_full_geometry_claim_is_enforced():
    from benchmarks import wallclock
    us = {"dense_psum": 100.0, "bitmap": 150.0}   # bitmap loses everywhere

    def mk(geometry):
        rows = []
        for live in wallclock.COLLECTIVE_LIVE_FRACS:
            for variant in wallclock.COLLECTIVE_VARIANTS:
                rows.append({
                    "table": "collective", "mesh": "8", "devices": 8,
                    "m": 8192, "n": 2048, "block": "128x2048",
                    "live_frac": live,
                    "cutoff": wallclock.COLLECTIVE_CUTOFF,
                    "variant": variant, "us_median": us[variant],
                    "us_iqr": 1.0, "reps": 7, "warmup": 2})
        return {"schema_version": wallclock.SCHEMA_VERSION,
                "bench": "BENCH_9", "jax_backend": "cpu",
                "geometry": geometry, "rows": rows}

    # smoke documents are exempt from the claim …
    assert wallclock.check_collective_schema(mk("smoke")) == []
    # … full documents are not: losing at the lowest live fraction and
    # past the cutoff both fail
    errs = wallclock.check_collective_schema(mk("full"))
    assert any("not faster" in e for e in errs)
    assert any("fallback" in e for e in errs)


# ---------------------------------------------------------------------------
# 8-device contracts (the actual mesh)
# ---------------------------------------------------------------------------

def _ffn_loss_and_batch(tokens=64):
    from repro.models.ffn import FFNConfig, ffn_apply, ffn_init
    cfg = FFNConfig(d_model=16, d_ff=32, activation="relu",
                    sparse_policy=PALLAS)
    params = ffn_init(jax.random.key(20), cfg)
    x = jax.random.normal(jax.random.key(21), (tokens, 16), jnp.float32)
    y = jax.random.normal(jax.random.key(22), (tokens, 16), jnp.float32)

    def loss_fn(p, batch):
        xb, yb = batch
        return jnp.mean((ffn_apply(p, xb, cfg) - yb) ** 2)

    return loss_fn, params, (x, y)


@requires8
def test_ffn_spmd_grads_match_single_device_jit():
    """The shard_map step is numerically the single-device jit of the
    same loss over the full batch (psum accumulation-order tolerance) —
    WITH the gradient all-reduce routed through the bitmap-compressed
    collective (the WG-bitmap registry hand-off survives the
    value_and_grad trace inside the shard_map body)."""
    loss_fn, params, batch = _ffn_loss_and_batch()
    mesh = jax.make_mesh((8,), ("data",))
    stats.reset()
    f = spmd_step.make_spmd_grad_fn(loss_fn, mesh)
    loss_s, grads_s = f(params, batch)
    jax.block_until_ready(loss_s)
    c = stats.counts()

    loss_j, grads_j = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    np.testing.assert_allclose(np.asarray(loss_s), np.asarray(loss_j),
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(grads_s), jax.tree.leaves(grads_j)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)

    # lifecycle contracts, mesh-wide: the body traces ONCE, so exactly
    # one fused encode per activation — and never a rescan anywhere
    assert sum(v for k, v in c.items()
               if k.startswith(("scan:", "scan_pallas:"))) == 0, c
    assert c.get("encode:act", 0) == 1, c
    # the FFN params are exactly two 2-D weight mats (no biases) and BOTH
    # registry peeks hit: every gradient leaf takes the compressed reduce
    assert c.get("collective:bitmap_psum", 0) == 2, c
    assert c.get("collective:dense", 0) == 0, c


@requires8
def test_cnn_spmd_grads_match_single_device_jit():
    """Same contract for the CNN (vgg16 smoke geometry, batch 8 → one
    image per shard): conv dW grads carry no registered bitmaps (only
    linear layers do), so their reduces are tagged dense — still zero
    rescans and one encode per activation across the mesh."""
    from repro.models.cnn import build_cnn
    model = build_cnn("vgg16", image_size=8, width=0.0625, num_classes=10)
    params = model.init(jax.random.key(30))
    img = jax.random.normal(jax.random.key(31), (8, 8, 8, 3), jnp.float32)
    lbl = jax.random.randint(jax.random.key(32), (8,), 0, 10)

    def loss_fn(p, batch):
        return model.loss(p, batch["img"], batch["lbl"], PALLAS)

    mesh = jax.make_mesh((8,), ("data",))
    stats.reset()
    f = spmd_step.make_spmd_grad_fn(loss_fn, mesh)
    loss_s, grads_s = f(params, {"img": img, "lbl": lbl})
    jax.block_until_ready(loss_s)
    c = stats.counts()
    assert sum(v for k, v in c.items()
               if k.startswith(("scan:", "scan_pallas:"))) == 0, c
    n_encodes = c.get("encode:act", 0)
    assert n_encodes >= 1, c

    loss_j, grads_j = jax.jit(jax.value_and_grad(loss_fn))(
        params, {"img": img, "lbl": lbl})
    np.testing.assert_allclose(np.asarray(loss_s), np.asarray(loss_j),
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(grads_s), jax.tree.leaves(grads_j)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)

    # the single-device trace must not have needed MORE encodes than the
    # whole mesh did: one per activation, period
    stats.reset()
    jax.block_until_ready(
        jax.jit(jax.value_and_grad(loss_fn))(params,
                                             {"img": img, "lbl": lbl}))
    assert stats.counts().get("encode:act", 0) == n_encodes


@requires8
def test_spmd_equivalent_across_mesh_shapes():
    """(8,) and (2, 4) meshes produce identical global grads — the
    collective is axis-set agnostic (psum over ('data',) ≡ over
    ('data', 'pod') when they cover the same devices)."""
    loss_fn, params, batch = _ffn_loss_and_batch()
    f1 = spmd_step.make_spmd_grad_fn(
        loss_fn, jax.make_mesh((8,), ("data",)))
    f2 = spmd_step.make_spmd_grad_fn(
        loss_fn, jax.make_mesh((2, 4), ("pod", "data")))
    l1, g1 = f1(params, batch)
    l2, g2 = f2(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@requires8
def test_sparse_psum_compressed_beats_union_of_uncorrelated_masks():
    """Uncorrelated per-shard masks union to ~dense: the runtime cutoff
    must detect that and take the dense fallback — per-shard sparsity
    that doesn't survive the union is not allowed to pretend."""
    n_dev, gran = 8, (4, 4)
    rng = np.random.default_rng(9)
    data = np.zeros((n_dev, 32, 32), np.float32)
    bits = np.zeros((n_dev, 8, 8), np.int32)
    for s in range(n_dev):
        bm = (rng.random((8, 8)) < 0.3).astype(np.int32)
        bm[0, 0] = 1
        bits[s] = bm
        data[s] = rng.standard_normal((32, 32)).astype(np.float32) \
            * np.repeat(np.repeat(bm, 4, 0), 4, 1)
    stats.reset()
    out, union = _reduce_fn(gran, cutoff=0.5)(
        jnp.asarray(data), jnp.asarray(bits))
    jax.block_until_ready(out)
    np.testing.assert_allclose(np.asarray(out), data.sum(0), atol=1e-5)
    c = stats.counts()
    # the union at 8 × 30% uncorrelated ≈ 94% live ⇒ every shard fell back
    assert c.get("collective:dense_fallback", 0) == n_dev, c


# ---------------------------------------------------------------------------
# 8-device bootstrap from a 1-device checkout
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_eight_device_rerun_subprocess():
    """Re-run this file's fast cells under an 8-virtual-device child
    process — the conftest-sanctioned way to get a mesh on a laptop.
    Skipped where the environment already provides ≥8 devices (CI's
    sharded-smoke job runs the file directly)."""
    if jax.device_count() >= 8:
        pytest.skip("already ≥8 devices; the cells above ran directly")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "not slow", __file__],
        env=env, capture_output=True, text=True, timeout=3000)
    assert proc.returncode == 0, proc.stdout + proc.stderr
