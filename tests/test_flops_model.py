"""Calibration of the analytic executed-cost model against XLA's own HLO
cost analysis on configs where HLO counting is sound (fully unrolled,
single device, microbatches=1).

XLA's cost analysis counts while-bodies once; with every scan unrolled the
compiled FLOPs are complete, and the analytic model must agree.  This is
the evidence that lets the full (necessarily scanned) cells trust the
analytic roofline terms in EXPERIMENTS.md.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.flops import analytic_cost
from repro.launch.steps import make_prefill_step, make_train_step
from repro.models.transformer import lm_init
from repro.optim.optimizer import OptConfig


def _hlo_flops(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def _small_dense(**kw):
    base = dict(
        name="cal", family="dense", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=512, ffn_activation="silu_glu",
        tie_embeddings=True, remat=False, scan_unroll=64,
        q_chunk=32, kv_chunk=32, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("schedule", ["rect", "tri"])
def test_train_flops_calibration_dense(schedule):
    cfg = _small_dense(attn_schedule=schedule)
    shape = ShapeConfig("cal", seq_len=128, global_batch=4, kind="train")
    params = jax.eval_shape(lambda: lm_init(jax.random.key(0), cfg))
    from repro.optim.optimizer import adamw_init
    opt = jax.eval_shape(adamw_init, params)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 129), jnp.int32)}
    step = make_train_step(cfg, OptConfig(), microbatches=1)
    hlo = _hlo_flops(step, params, opt, batch)
    ana = analytic_cost(cfg, shape, dp_n=1, model_n=1).flops_per_device
    ratio = ana / hlo
    assert 0.6 < ratio < 1.6, (ana, hlo, ratio)


def test_prefill_flops_calibration_dense():
    cfg = _small_dense()
    shape = ShapeConfig("cal", seq_len=256, global_batch=2, kind="prefill")
    params = jax.eval_shape(lambda: lm_init(jax.random.key(0), cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 257), jnp.int32)}
    hlo = _hlo_flops(make_prefill_step(cfg), params, batch)
    ana = analytic_cost(cfg, shape, dp_n=1, model_n=1).flops_per_device
    assert 0.6 < ana / hlo < 1.6, (ana, hlo)


def test_train_flops_calibration_moe():
    from repro.models.moe import MoEConfig
    cfg = _small_dense(
        family="moe",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256,
                      activation="silu_glu"))
    shape = ShapeConfig("cal", seq_len=128, global_batch=4, kind="train")
    params = jax.eval_shape(lambda: lm_init(jax.random.key(0), cfg))
    from repro.optim.optimizer import adamw_init
    opt = jax.eval_shape(adamw_init, params)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 129), jnp.int32)}
    step = make_train_step(cfg, OptConfig(), microbatches=1)
    hlo = _hlo_flops(step, params, opt, batch)
    ana = analytic_cost(cfg, shape, dp_n=1, model_n=1).flops_per_device
    assert 0.55 < ana / hlo < 1.8, (ana, hlo)


def test_remat_factor_visible():
    """remat=True must cost exactly one extra forward in the model."""
    shape = ShapeConfig("cal", seq_len=128, global_batch=4, kind="train")
    a_no = analytic_cost(_small_dense(remat=False), shape, dp_n=1, model_n=1)
    a_yes = analytic_cost(_small_dense(remat=True), shape, dp_n=1, model_n=1)
    r = a_yes.detail["matmul_flops"] / a_no.detail["matmul_flops"]
    assert abs(r - 4.0 / 3.0) < 1e-6
