"""Checkpointing: atomicity, keep-k, resume-equivalence (fault tolerance)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import SMOKE_ARCHS
from repro.configs.base import TrainConfig
from repro.launch.train import StragglerDetector, train_loop


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    step, back = ckpt.restore(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_keep_last_k_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_crashed_tmp_dirs_are_invisible_and_cleaned(tmp_path):
    t = _tree()
    # simulate a crashed writer
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 1, t)
    assert ckpt.latest_step(str(tmp_path)) == 1
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.zeros((3, 3))})
    with pytest.raises(ckpt.CheckpointError) as ei:
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((4, 4))},
                     step=1)                  # explicit step: no fallback
    assert ei.value.step == 1                 # typed context, not a bare
    assert ei.value.leaf == "leaf_00000"      # assert (python -O erases)


def test_crash_mid_save_restores_previous_and_prunes(tmp_path):
    """A writer that died mid-save (planted .tmp dir) plus a torn final
    write (truncated leaves.npz) in the newest step: auto-resume must land
    on the previous intact checkpoint, count the fallback, and the next
    save must clear all wreckage."""
    from repro.kernels import stats
    t2, t4 = _tree(2), _tree(4)
    ckpt.save(str(tmp_path), 2, t2)
    ckpt.save(str(tmp_path), 4, t4)
    os.makedirs(tmp_path / "step_00000006.tmp")     # crashed writer
    with open(tmp_path / "step_00000004" / "leaves.npz", "r+b") as f:
        f.truncate(10)                              # torn newest payload
    step, back = ckpt.restore(str(tmp_path), t2)
    assert step == 2
    for a, b in zip(jax.tree.leaves(t2), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)
    assert stats.guard_counts().get("guard:ckpt_fallback", 0) >= 1
    # the corrupt newest was quarantined, not offered again
    assert ckpt.latest_step(str(tmp_path)) == 2
    assert any(d.endswith(".corrupt") for d in os.listdir(tmp_path))
    ckpt.save(str(tmp_path), 6, t4)                 # next save prunes
    left = os.listdir(tmp_path)
    assert not any(d.endswith((".tmp", ".old", ".corrupt")) for d in left)


def test_unreadable_npz_is_typed_not_raw(tmp_path):
    """np.load failures surface as CheckpointCorruptError (the
    fallback-able class), never a raw zipfile/OS error."""
    ckpt.save(str(tmp_path), 3, _tree())
    with open(tmp_path / "step_00000003" / "leaves.npz", "wb") as f:
        f.write(b"not a zip")
    with pytest.raises(ckpt.CheckpointCorruptError) as ei:
        ckpt.restore(str(tmp_path), _tree(), step=3)
    assert ei.value.step == 3
    # auto-resume with everything corrupt: typed terminal error
    with pytest.raises(ckpt.CheckpointError):
        ckpt.restore(str(tmp_path), _tree())


def test_same_step_rewrite_never_destroys_previous(tmp_path):
    """Re-saving an existing step keeps the old dir until the new commit
    lands (moved aside, deleted after) — and an injected crash at the
    commit point leaves the ORIGINAL intact."""
    from repro.runtime import faults
    t = _tree(1)
    ckpt.save(str(tmp_path), 5, t)
    faults.arm(faults.Fault("checkpoint:pre_commit", "crash"))
    try:
        with pytest.raises(faults.InjectedCrash):
            ckpt.save(str(tmp_path), 5, _tree(9))
    finally:
        faults.disarm()
    step, back = ckpt.restore(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_state_json_roundtrip(tmp_path):
    ckpt.save(str(tmp_path), 2, _tree(), extra={"autotune": {"x": 1}})
    assert ckpt.load_state(str(tmp_path)) == {"autotune": {"x": 1}}
    assert ckpt.load_state(str(tmp_path), 2) == {"autotune": {"x": 1}}
    ckpt.save(str(tmp_path), 4, _tree())          # no extra
    assert ckpt.load_state(str(tmp_path)) is None
    assert ckpt.load_state(str(tmp_path), 2) == {"autotune": {"x": 1}}


def test_train_resume_bit_identical(tmp_path):
    """Kill-and-restart == uninterrupted run (checkpoint + deterministic
    data cursor) — the core fault-tolerance property."""
    cfg = SMOKE_ARCHS["smollm-360m"]
    tcfg = TrainConfig(total_steps=6, checkpoint_every=3, learning_rate=1e-3,
                       seed=3)
    # uninterrupted
    full = train_loop(cfg, tcfg, batch_size=4, seq_len=16, steps=6,
                      ckpt_dir=None, log_every=0)
    # interrupted at step 3, then resumed
    d = str(tmp_path / "ck")
    train_loop(cfg, tcfg, batch_size=4, seq_len=16, steps=3,
               ckpt_dir=d, log_every=0)
    resumed = train_loop(cfg, tcfg, batch_size=4, seq_len=16, steps=6,
                         ckpt_dir=d, resume=True, log_every=0)
    assert resumed["resumed_from"] == 3
    np.testing.assert_allclose(full["losses"][3:], resumed["losses"],
                               rtol=1e-5, atol=1e-6)


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(window=16, threshold=2.0)
    for i in range(20):
        det.observe(i, 0.1)
    assert det.observe(20, 0.5)          # 5× median
    assert not det.observe(21, 0.12)
    assert len(det.flags) == 1
