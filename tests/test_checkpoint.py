"""Checkpointing: atomicity, keep-k, resume-equivalence (fault tolerance)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import SMOKE_ARCHS
from repro.configs.base import TrainConfig
from repro.launch.train import StragglerDetector, train_loop


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    step, back = ckpt.restore(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_keep_last_k_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_crashed_tmp_dirs_are_invisible_and_cleaned(tmp_path):
    t = _tree()
    # simulate a crashed writer
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 1, t)
    assert ckpt.latest_step(str(tmp_path)) == 1
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.zeros((3, 3))})
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((4, 4))})


def test_train_resume_bit_identical(tmp_path):
    """Kill-and-restart == uninterrupted run (checkpoint + deterministic
    data cursor) — the core fault-tolerance property."""
    cfg = SMOKE_ARCHS["smollm-360m"]
    tcfg = TrainConfig(total_steps=6, checkpoint_every=3, learning_rate=1e-3,
                       seed=3)
    # uninterrupted
    full = train_loop(cfg, tcfg, batch_size=4, seq_len=16, steps=6,
                      ckpt_dir=None, log_every=0)
    # interrupted at step 3, then resumed
    d = str(tmp_path / "ck")
    train_loop(cfg, tcfg, batch_size=4, seq_len=16, steps=3,
               ckpt_dir=d, log_every=0)
    resumed = train_loop(cfg, tcfg, batch_size=4, seq_len=16, steps=6,
                         ckpt_dir=d, resume=True, log_every=0)
    assert resumed["resumed_from"] == 3
    np.testing.assert_allclose(full["losses"][3:], resumed["losses"],
                               rtol=1e-5, atol=1e-6)


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(window=16, threshold=2.0)
    for i in range(20):
        det.observe(i, 0.1)
    assert det.observe(20, 0.5)          # 5× median
    assert not det.observe(21, 0.12)
    assert len(det.flags) == 1
