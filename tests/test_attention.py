"""Attention correctness: blockwise (flash-style) == naive; tri == rect;
MLA absorbed decode == expanded training path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (AttnConfig, attn_apply, attn_decode,
                                    attn_init, blockwise_attention,
                                    init_cache)


def _naive(q, k, v, causal=True, window=None):
    b, t, hq, d = q.shape
    s = k.shape[1]
    g = hq // k.shape[2]
    qg = q.reshape(b, t, k.shape[2], g, d)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * d ** -0.5
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= jnp.arange(t)[:, None] >= jnp.arange(s)[None, :]
    if window is not None:
        mask &= jnp.arange(t)[:, None] - jnp.arange(s)[None, :] < window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, t, hq, d)


@pytest.mark.parametrize("schedule", ["rect", "tri"])
@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("hq,hk", [(4, 4), (6, 2)])
def test_blockwise_matches_naive(schedule, window, hq, hk):
    b, t, d = 2, 40, 16
    rng = jax.random.key(0)
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (b, t, hq, d))
    k = jax.random.normal(kk, (b, t, hk, d))
    v = jax.random.normal(kv, (b, t, hk, d))
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              q_chunk=16, kv_chunk=8, schedule=schedule)
    want = _naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_noncausal_blockwise():
    b, t, s, h, d = 2, 12, 20, 2, 8
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    got = blockwise_attention(q, k, v, causal=False, q_chunk=8, kv_chunk=8)
    want = _naive(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def _decode_vs_apply(cfg, t=12):
    params = attn_init(jax.random.key(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, t, cfg.d_model))
    full = attn_apply(params, x, cfg)
    cache = init_cache(cfg, 2, t, jnp.float32)
    for i in range(t):
        out, cache = attn_decode(params, x[:, i:i + 1], cache,
                                 jnp.asarray(i, jnp.int32), cfg)
    np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=3e-4, atol=3e-4)


def test_gqa_decode_equals_training_path():
    _decode_vs_apply(AttnConfig(d_model=32, n_heads=4, n_kv_heads=2,
                                head_dim=8, q_chunk=4, kv_chunk=4))


def test_sliding_window_ring_buffer_decode():
    _decode_vs_apply(AttnConfig(d_model=32, n_heads=4, n_kv_heads=2,
                                head_dim=8, window=5, q_chunk=4, kv_chunk=4))


def test_mla_absorbed_decode_equals_training_path():
    _decode_vs_apply(AttnConfig(d_model=32, n_heads=2, n_kv_heads=2,
                                head_dim=16, use_mla=True, kv_lora_rank=16,
                                qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
                                q_chunk=4, kv_chunk=4))


def test_tri_schedule_flops_reduction_is_modeled():
    """The analytic model sees tri ≈ half the rect attention FLOPs."""
    from repro.configs import ARCHS
    from repro.configs.base import TRAIN_4K
    from repro.launch.flops import analytic_cost
    cfg = ARCHS["stablelm-1.6b"]
    rect = analytic_cost(cfg, TRAIN_4K, dp_n=16, model_n=16)
    tri = analytic_cost(cfg.with_(attn_schedule="tri"), TRAIN_4K,
                        dp_n=16, model_n=16)
    r = tri.detail["attn_flops"] / rect.detail["attn_flops"]
    assert 0.45 < r < 0.65
